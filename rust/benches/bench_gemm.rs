//! GEMM benchmarks: the microkernel generations (per-arch SIMD vs i16
//! pair-accumulation vs PR-1 wide-i32 vs seed kernel) across the
//! register-tile grid, the runtime kernel dispatch resolution,
//! thread scaling, the skinny-M decode GEMV vs the tile cascade, the
//! quantize-compute-dequant pipelines of each method, end-to-end
//! `nll_per_seq` throughput through the true-INT pipeline, and
//! incremental decode tokens/s through the KV-cache session API
//! (`decode_tok_s` — the latency-bound serving number), speculative
//! draft-and-verify decode (`decode_tok_s_spec`, with its acceptance
//! rate and tokens-per-round), and the W4 nibble weight path
//! (`decode_tok_s_w4` / `decode_tok_s_resq` and the packed-panel byte
//! halving `w4_weight_bytes_ratio`), plus the rotated W4A8 pipeline
//! (`decode_tok_s_rot` — what the per-row inverse rotation costs at
//! decode widths).
//! (The NPU projection lives in bench_npusim / npu_latency.)
//!
//! Run: `cargo bench --bench bench_gemm`. Writes the perf-trajectory
//! record to `$MUXQ_BENCH_JSON` (default `BENCH_gemm.json`); the CI
//! smoke gate is rust/scripts/bench_check.sh (doc/test hygiene:
//! rust/scripts/ci_check.sh).

use muxq::data::prng::SplitMix64;
use muxq::gpt2::speculative::DRAFT_SEED_SALT;
use muxq::gpt2::{
    argmax, DraftKind, DraftModel, Gpt2Model, KvPool, PrefixCache, QuantizedGpt2, Sampler,
    SessionModel, SessionState, SpeculativeState, WrapPolicy,
};
use muxq::quant::EngineSpec;
use muxq::quant::gemm::{matmul_f32, quant_matmul};
use muxq::quant::llmint8::llmint8_matmul;
use muxq::quant::matrix::{MatI32, MatI8};
use muxq::quant::muxq::{muxq_matmul_int, MuxqParams};
use muxq::quant::packed::{
    matmul_i8_gemv_into, matmul_i8_packed_kernel_into, matmul_i8_packed_with,
    matmul_i8w4_gemv_into, Kernel, PackedMatI4, PackedMatI8, ParallelGemm,
};
use muxq::quant::simd;
use muxq::quant::{Granularity, MatF32};
use muxq::util::bench::Bencher;

fn mat(rows: usize, cols: usize, seed: u64, outliers: &[usize]) -> MatF32 {
    let mut rng = SplitMix64::new(seed);
    let mut m = MatF32::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
    )
    .unwrap();
    for r in 0..rows {
        for &c in outliers {
            *m.at_mut(r, c) *= 25.0;
        }
    }
    m
}

fn rand_i8(rows: usize, cols: usize, seed: u64) -> MatI8 {
    let mut rng = SplitMix64::new(seed);
    let mut m = MatI8::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = (rng.next_below(255) as i32 - 127) as i8;
    }
    m
}

/// The seed repo's i8 kernel, verbatim (cache-blocked, zero-skip branch
/// in the inner loop) — kept here as the before-side of the packed-engine
/// comparison so the speedup stays measurable across PRs.
fn seed_matmul_i8(a: &MatI8, b: &MatI8) -> MatI32 {
    const BM: usize = 32;
    const BK: usize = 64;
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = MatI32::zeros(m, n);
    for i0 in (0..m).step_by(BM) {
        let i1 = (i0 + BM).min(m);
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in i0..i1 {
                let arow = a.row(i);
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk] as i32;
                    if av == 0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += av * *bv as i32;
                    }
                }
            }
        }
    }
    c
}

fn main() {
    let mut b = Bencher::default();
    let p = MuxqParams::default();

    // ---- packed engine vs seed kernel (the perf-trajectory numbers) ----
    let (gm, gk, gn) = (512usize, 768usize, 768usize);
    Bencher::header(&format!("packed i8 GEMM vs seed kernel ({gm}x{gk}x{gn})"));
    let xq = rand_i8(gm, gk, 11);
    let wq = rand_i8(gk, gn, 12);
    let seed_ms = b
        .bench("seed_i8 (blocked, zero-skip branch)", || seed_matmul_i8(&xq, &wq))
        .mean
        .as_secs_f64()
        * 1e3;
    let packed = PackedMatI8::pack(&wq);
    let mut per_thread_ms: Vec<(usize, f64)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let cfg = ParallelGemm { threads, min_parallel_macs: 0 };
        let ms = b
            .bench(&format!("packed_i8/{threads}t"), || matmul_i8_packed_with(&xq, &packed, cfg))
            .mean
            .as_secs_f64()
            * 1e3;
        per_thread_ms.push((threads, ms));
    }
    b.bench("pack_weights (once per weight, amortized)", || PackedMatI8::pack(&wq));
    let packed_1t_ms = per_thread_ms[0].1;
    let packed_4t_ms = per_thread_ms[2].1;
    let gops_1t = 2.0 * (gm * gk * gn) as f64 / (packed_1t_ms / 1e3) / 1e9;
    println!(
        "\npacked vs seed (1 thread): {:.2}x   scaling 1t->4t: {:.2}x   {:.2} GOPS/thread",
        seed_ms / packed_1t_ms,
        packed_1t_ms / packed_4t_ms,
        gops_1t
    );

    // ---- microkernel generations across the register-tile grid ----
    // pair_i16 = the i16 pair-accumulation kernel (PR 2, two MACs/lane),
    // wide_i32 = the PR-1 scheme (one MAC/lane); wide_i32 at 4x4 is the
    // PR-1 packed engine verbatim, the before-side of this comparison.
    Bencher::header(&format!("microkernel tile grid ({gm}x{gk}x{gn}, 1 thread)"));
    let seq = ParallelGemm::sequential();
    let mut acc = MatI32::zeros(0, 0);
    let mut grid: Vec<(usize, usize, f64, f64)> = Vec::new();
    for &(mr, nr) in &[(4usize, 4usize), (4, 8), (8, 4), (8, 8)] {
        let bp = PackedMatI8::pack_with(&wq, nr);
        let pair_ms = b
            .bench(&format!("pair_i16/{mr}x{nr}"), || {
                matmul_i8_packed_kernel_into(&xq, &bp, &mut acc, seq, Kernel::PairI16, mr);
                acc.data[0]
            })
            .mean
            .as_secs_f64()
            * 1e3;
        let wide_ms = b
            .bench(&format!("wide_i32/{mr}x{nr}"), || {
                matmul_i8_packed_kernel_into(&xq, &bp, &mut acc, seq, Kernel::WideI32, mr);
                acc.data[0]
            })
            .mean
            .as_secs_f64()
            * 1e3;
        grid.push((mr, nr, pair_ms, wide_ms));
    }
    let wide44_ms = grid[0].3;
    let (best_mr, best_nr, pair_best_ms) = grid
        .iter()
        .map(|&(mr, nr, p, _)| (mr, nr, p))
        .fold((4, 4, f64::INFINITY), |best, cur| if cur.2 < best.2 { cur } else { best });
    println!(
        "\nbest pair tile {best_mr}x{best_nr}: {pair_best_ms:.2}ms \
         ({:.2}x vs PR-1 wide_i32 4x4 at {wide44_ms:.2}ms)",
        wide44_ms / pair_best_ms
    );

    // ---- skinny-M decode GEMV vs the register-tile cascade ----
    // the per-token decode projection is M=1 against a pre-packed weight;
    // the GEMV path drops the A-interleave copy and tile dispatch the
    // cascade pays per call
    Bencher::header(&format!("skinny-M decode path ({gk}x{gn} packed weight, 1 thread)"));
    let bp_dec = PackedMatI8::pack(&wq);
    let mut gemv_m1_us = 0.0f64;
    let mut gemv_vs_cascade_m1 = 0.0f64;
    for m in [1usize, 4] {
        let xs = rand_i8(m, gk, 40 + m as u64);
        let cas_us = b
            .bench(&format!("tile_cascade/m={m}"), || {
                matmul_i8_packed_kernel_into(&xs, &bp_dec, &mut acc, seq, Kernel::Auto, 4);
                acc.data[0]
            })
            .mean
            .as_secs_f64()
            * 1e6;
        let gemv_us = b
            .bench(&format!("gemv/m={m}"), || {
                matmul_i8_gemv_into(&xs, &bp_dec, &mut acc, Kernel::Auto);
                acc.data[0]
            })
            .mean
            .as_secs_f64()
            * 1e6;
        if m == 1 {
            gemv_m1_us = gemv_us;
            gemv_vs_cascade_m1 = cas_us / gemv_us;
        }
    }
    println!("\ngemv m=1: {gemv_m1_us:.1}us ({gemv_vs_cascade_m1:.2}x vs tile cascade)");

    // ---- kernel dispatch: per-arch SIMD vs the scalar generations ----
    // the runtime dispatcher's resolution for this host, then the SIMD
    // kernels (AVX2 pmaddwd / NEON sdot-smlal) explicitly forced across
    // the tile grid against the best scalar pair tile — the
    // autovectorization-vs-intrinsics gap the ROADMAP item called out
    let dispatch = simd::dispatch();
    let caps = simd::host_caps();
    Bencher::header(&format!(
        "kernel dispatch ({gm}x{gk}x{gn}, 1 thread) — resolved: {} \
         (caps: avx2={} neon={} neon_dot={})",
        dispatch.name(),
        caps.avx2,
        caps.neon,
        caps.neon_dot
    ));
    let mut simd_best: Option<(usize, usize, f64)> = None;
    if simd::host_simd().is_some() {
        for &(mr, nr) in &[(4usize, 4usize), (4, 8), (8, 4), (8, 8)] {
            let bp = PackedMatI8::pack_with(&wq, nr);
            let ms = b
                .bench(&format!("simd/{mr}x{nr}"), || {
                    matmul_i8_packed_kernel_into(&xq, &bp, &mut acc, seq, Kernel::Simd, mr);
                    acc.data[0]
                })
                .mean
                .as_secs_f64()
                * 1e3;
            if simd_best.is_none_or(|(_, _, best)| ms < best) {
                simd_best = Some((mr, nr, ms));
            }
        }
        // the decode shape through the SIMD GEMV kernels
        let bp_g = PackedMatI8::pack(&wq);
        let x1 = rand_i8(1, gk, 41);
        b.bench("simd_gemv/m=1", || {
            matmul_i8_gemv_into(&x1, &bp_g, &mut acc, Kernel::Simd);
            acc.data[0]
        });
        let (bm, bn, bms) = simd_best.unwrap();
        println!(
            "\nbest simd tile {bm}x{bn}: {bms:.2}ms ({:.2}x vs best scalar pair at \
             {pair_best_ms:.2}ms)",
            pair_best_ms / bms
        );
    } else {
        println!("no SIMD kernel on this host; simd_* JSON fields stay null");
    }

    // ---- quantize-compute-dequant pipelines per method ----
    for (m, k, n, label) in [
        (256, 512, 512, "c_fc-like 256x512x512"),
        (1024, 256, 1024, "sim-large c_fc 1024x256x1024"),
    ] {
        Bencher::header(&format!("GEMM pipelines ({label}, 8 outlier cols)"));
        let x = mat(m, k, 1, &[1, 30, 60, 90, 120, 150, 180, 210]);
        let w = mat(k, n, 2, &[]);
        b.bench("fp32_reference", || matmul_f32(&x, &w));
        b.bench("naive_int8 (quant+i8gemm+dequant)", || {
            quant_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol)
        });
        b.bench("muxq_int8 (body+skinny aux)", || {
            muxq_matmul_int(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, &p)
        });
        b.bench("llmint8 (int8 + fp16 outlier path)", || {
            llmint8_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, 6.0)
        });
    }

    let naive = b
        .results
        .iter()
        .find(|r| r.name.starts_with("naive_int8"))
        .unwrap()
        .mean
        .as_secs_f64();
    let muxq = b
        .results
        .iter()
        .find(|r| r.name.starts_with("muxq_int8"))
        .unwrap()
        .mean
        .as_secs_f64();
    println!("\nmuxq INT pipeline overhead vs naive INT (first shape): {:.2}x", muxq / naive);

    // ---- end-to-end: nll_per_seq through the zero-copy INT pipeline ----
    let (nb, ns) = (4usize, 32usize);
    let tokens: Vec<Vec<u32>> = {
        let mut rng = SplitMix64::new(21);
        (0..nb).map(|_| (0..ns).map(|_| rng.next_below(128) as u32).collect()).collect()
    };
    Bencher::header(&format!("end-to-end nll_per_seq (2L d=128, batch {nb}x{ns} tokens)"));
    let mut e2e_tok_s: Vec<(&str, f64)> = Vec::new();
    for (spec, name) in [(EngineSpec::naive(), "naive"), (EngineSpec::muxq(), "muxq")] {
        let q = QuantizedGpt2::new(Gpt2Model::test_model(2, 128, 2, 64, 128, 7), spec);
        let stats = b.bench(&format!("nll_per_seq/{name}"), || q.nll_per_seq(&tokens).unwrap());
        let tok_s = (nb * ns) as f64 * stats.per_sec();
        e2e_tok_s.push((name, tok_s));
    }
    for (name, tok_s) in &e2e_tok_s {
        println!("nll_per_seq/{name}: {tok_s:.0} tokens/s");
    }

    // ---- incremental decode tokens/s (session API) ----
    // steady-state single-session decode through the KV cache (Slide
    // policy: fixed window, no re-prefill spikes inside the timing
    // loop), against the O(S^2)-per-token full re-forward the old
    // generate path paid. Decode cost is per-STEP, independent of how
    // many tokens were already generated.
    Bencher::header("incremental decode (2L d=128 n_ctx=64, 16-token prompt)");
    let prompt: Vec<u32> = {
        let mut rng = SplitMix64::new(31);
        (0..16).map(|_| rng.next_below(128) as u32).collect()
    };
    // per-method decode throughput through the SAME operator API the
    // generation server runs — llm.int8() now has a deployed number too
    let mut decode_tok_s = [0.0f64; 3]; // [fp32, muxq, llmint8]
    for (slot, label, spec) in [
        (0usize, "fp32", None),
        (1, "muxq", Some(EngineSpec::muxq())),
        (2, "llmint8", Some(EngineSpec::llmint8())),
    ] {
        let fp = Gpt2Model::test_model(2, 128, 2, 64, 128, 7);
        let q = spec.map(|s| QuantizedGpt2::new(fp.clone(), s));
        let mut sess = match &q {
            None => fp.session(WrapPolicy::Slide),
            Some(qq) => qq.session(WrapPolicy::Slide),
        };
        let mut next = argmax(&sess.prefill(&prompt).unwrap());
        let stats = b.bench(&format!("decode_step/{label}"), || {
            let l = sess.decode_step(next).unwrap();
            next = argmax(&l);
            next
        });
        decode_tok_s[slot] = stats.per_sec();
    }
    // the pre-refactor comparator: one token costs a FULL forward over
    // the whole 32-token context (and grows as the context grows)
    let fp_full = Gpt2Model::test_model(2, 128, 2, 64, 128, 7);
    let q_full = QuantizedGpt2::new(fp_full.clone(), EngineSpec::muxq());
    let ctx32: Vec<Vec<u32>> = {
        let mut rng = SplitMix64::new(32);
        vec![(0..32).map(|_| rng.next_below(128) as u32).collect()]
    };
    let full_stats =
        b.bench("full_forward_per_token/muxq (S=32)", || {
            q_full.forward_logits_session(&ctx32).unwrap().data[0]
        });
    let full_tok_s = full_stats.per_sec();
    let decode_vs_full = decode_tok_s[1] / full_tok_s;
    println!(
        "\ndecode fp32 {:.0} tok/s   muxq {:.0} tok/s   llmint8 {:.0} tok/s   \
         vs full re-forward {:.0} tok/s ({decode_vs_full:.1}x, growing with S)",
        decode_tok_s[0], decode_tok_s[1], decode_tok_s[2], full_tok_s
    );

    // ---- speculative decode tokens/s (draft-and-verify) ----
    // steady-state rounds over the SAME muxq backend: a trunc-1 draft
    // proposes k=3 tokens, the target verifies them in one skinny
    // batched forward. tokens/s = tokens-per-round x rounds/s; greedy
    // acceptance, so the emitted stream equals plain decode.
    Bencher::header("speculative decode (muxq target, trunc1 draft, k=3)");
    let fp_spec = Gpt2Model::test_model(2, 128, 2, 64, 128, 7);
    let q_spec = QuantizedGpt2::new(fp_spec, EngineSpec::muxq());
    let sm_spec = SessionModel::Int(&q_spec);
    let draft = DraftModel::build(&q_spec.fp, DraftKind::TruncateLayers(1)).unwrap();
    let mut spec_st =
        SpeculativeState::new(&q_spec.fp.cfg, draft.cfg(), 3, WrapPolicy::default()).unwrap();
    let mut smp = Sampler::greedy();
    let mut dsm = smp.fork(DRAFT_SEED_SALT);
    let mut next = argmax(&spec_st.prefill(sm_spec, draft.session_model(), &prompt).unwrap());
    let round_stats = b.bench("spec_round/muxq-k3-trunc1", || {
        let toks = spec_st.round(sm_spec, draft.session_model(), next, &mut smp, &mut dsm).unwrap();
        next = *toks.last().unwrap();
        toks.len()
    });
    let spec_accept_rate = spec_st.accept_rate();
    let spec_tokens_per_round = spec_st.tokens_per_round();
    let decode_tok_s_spec = spec_tokens_per_round * round_stats.per_sec();
    println!(
        "\nspec decode {decode_tok_s_spec:.0} tok/s ({:.2}x vs plain muxq decode)   \
         accept-rate {spec_accept_rate:.2}   tokens/round {spec_tokens_per_round:.2}",
        decode_tok_s_spec / decode_tok_s[1]
    );

    // ---- W4 nibble decode (the halved weight stream) ----
    // the nibble panel stores two i4 weights per byte — exactly half
    // the W8 engine's packed-panel bytes (layout arithmetic, recorded
    // as w4_weight_bytes_ratio). At decode widths the weight stream IS
    // the cost, so the halving is measured where it pays: the M=1 GEMV
    // against a pre-packed W4 weight, then full serving-path decode for
    // the W4 deployments (naive-w4a8, and resq = W4 body + rank-r fp32
    // residual through the gathered-rows kernel).
    Bencher::header(&format!("w4 nibble decode ({gk}x{gn} weight, 2L d=128 session)"));
    let wq4 = MatI8 {
        rows: wq.rows,
        cols: wq.cols,
        data: wq.data.iter().map(|&v| v >> 4).collect(), // i4 range [-8, 7]
    };
    let bp4 = PackedMatI4::pack(&wq4);
    let w4_weight_bytes_ratio = bp_dec.padded_bytes() as f64 / bp4.padded_bytes() as f64;
    let x1w = rand_i8(1, gk, 42);
    b.bench("w4_gemv/m=1", || {
        matmul_i8w4_gemv_into(&x1w, &bp4, &mut acc, Kernel::Auto);
        acc.data[0]
    });
    let mut w4_tok_s = [0.0f64; 3]; // [naive-w4a8, resq, naive-w4a8-rot]
    for (slot, label, spec) in [
        (0usize, "naive-w4a8", EngineSpec::naive().with_bits(8, 4)),
        (1, "resq", EngineSpec::resq()),
        // the rotated pipeline: blockwise-orthogonal pre-transform folded
        // into the nibble panel at pack time, inverse rotation paid per
        // activation row — decode_tok_s_rot prices that per-token cost
        (2, "naive-w4a8-rot", EngineSpec::naive().with_bits(8, 4).with_rotate()),
    ] {
        let q = QuantizedGpt2::new(Gpt2Model::test_model(2, 128, 2, 64, 128, 7), spec);
        let mut sess = q.session(WrapPolicy::Slide);
        let mut next = argmax(&sess.prefill(&prompt).unwrap());
        let stats = b.bench(&format!("decode_step/{label}"), || {
            let l = sess.decode_step(next).unwrap();
            next = argmax(&l);
            next
        });
        w4_tok_s[slot] = stats.per_sec();
    }
    let (decode_tok_s_w4, decode_tok_s_resq, decode_tok_s_rot) =
        (w4_tok_s[0], w4_tok_s[1], w4_tok_s[2]);
    println!(
        "\nw4 decode {decode_tok_s_w4:.0} tok/s ({:.2}x vs muxq w8 decode)   \
         resq {decode_tok_s_resq:.0} tok/s   rot {decode_tok_s_rot:.0} tok/s   \
         weight bytes {w4_weight_bytes_ratio:.2}x smaller",
        decode_tok_s_w4 / decode_tok_s[1]
    );

    // ---- paged KV serving (pool occupancy + prefix sharing) ----
    // four sessions share the 16-token system prompt copy-on-write:
    // paged_fill is the pool occupancy that results, shared_page_ratio
    // the peak fraction of the pool serving more than one owner — the
    // two ratios the serving stats surface, recorded here so the
    // baseline tracks them across PRs. Paged decode itself is also
    // timed: same operator path as the ring, only the KV addressing
    // changes.
    Bencher::header("paged KV (96-page pool, 8 rows/page, shared 16-token prefix)");
    let pool = KvPool::new(96, 8, q_spec.fp.cfg.d_model);
    let mut pc = PrefixCache::new(pool.clone(), 8);
    let mut paged_sessions = Vec::new();
    for t in 0..4u32 {
        let mut s = SessionState::new_paged(&q_spec.fp.cfg, WrapPolicy::Slide, &pool);
        let mut p = prompt.clone();
        p.push(t);
        s.prefill_cached(sm_spec, &p, &mut pc).unwrap();
        paged_sessions.push(s);
    }
    pool.note_shared(paged_sessions.iter().map(|s| s.shared_pages()).sum());
    let paged_fill = pool.pages_in_use() as f64 / pool.capacity() as f64;
    let shared_page_ratio = pool.shared_pages_note() as f64 / pool.capacity() as f64;
    {
        let sess = &mut paged_sessions[0];
        let mut next = 1u32;
        let stats = b.bench("decode_step/paged-muxq", || {
            let l = sess.decode_step(sm_spec, next).unwrap();
            next = argmax(&l);
            next
        });
        println!(
            "\npaged decode {:.0} tok/s ({:.2}x vs ring muxq decode)   \
             pool fill {paged_fill:.2}   shared-page ratio {shared_page_ratio:.2}",
            stats.per_sec(),
            stats.per_sec() / decode_tok_s[1]
        );
    }
    drop(paged_sessions);

    // ---- perf-trajectory record ----
    // packed_*_ms track the auto-routed engine (dispatch-selected
    // kernel + tile); wide44_1t_ms pins the PR-1 comparator so the
    // pair-vs-wide trajectory stays measurable across PRs, and the
    // simd_* fields pin intrinsics-vs-autovectorized-pair (null on
    // hosts without a SIMD kernel).
    let (simd_best_ms_s, simd_best_tile_s, simd_vs_pair_s) = match simd_best {
        Some((bm, bn, bms)) => (
            format!("{bms:.4}"),
            format!("\"{bm}x{bn}\""),
            format!("{:.3}", pair_best_ms / bms),
        ),
        None => ("null".to_string(), "null".to_string(), "null".to_string()),
    };
    let json = format!(
        "{{\n  \"bench\": \"bench_gemm\",\n  \"bootstrap\": false,\n  \"shape\": [{gm}, {gk}, {gn}],\n  \"dispatch_kernel\": \"{}\",\n  \"seed_i8_ms\": {seed_ms:.4},\n  \"packed_1t_ms\": {:.4},\n  \"packed_2t_ms\": {:.4},\n  \"packed_4t_ms\": {:.4},\n  \"speedup_vs_seed_1t\": {:.3},\n  \"scaling_1t_to_4t\": {:.3},\n  \"gops_packed_1t\": {:.3},\n  \"pair_best_ms\": {pair_best_ms:.4},\n  \"pair_best_tile\": \"{best_mr}x{best_nr}\",\n  \"wide44_1t_ms\": {wide44_ms:.4},\n  \"pair_vs_wide44\": {:.3},\n  \"simd_best_ms\": {simd_best_ms_s},\n  \"simd_best_tile\": {simd_best_tile_s},\n  \"simd_vs_pair\": {simd_vs_pair_s},\n  \"gemv_m1_us\": {gemv_m1_us:.2},\n  \"gemv_vs_cascade_m1\": {gemv_vs_cascade_m1:.3},\n  \"e2e_naive_tok_per_s\": {:.1},\n  \"e2e_muxq_tok_per_s\": {:.1},\n  \"decode_tok_s_fp\": {:.1},\n  \"decode_tok_s\": {:.1},\n  \"decode_tok_s_llmint8\": {:.1},\n  \"decode_tok_s_w4\": {decode_tok_s_w4:.1},\n  \"decode_tok_s_resq\": {decode_tok_s_resq:.1},\n  \"decode_tok_s_rot\": {decode_tok_s_rot:.1},\n  \"w4_weight_bytes_ratio\": {w4_weight_bytes_ratio:.3},\n  \"decode_tok_s_spec\": {decode_tok_s_spec:.1},\n  \"spec_accept_rate\": {spec_accept_rate:.3},\n  \"spec_tokens_per_round\": {spec_tokens_per_round:.3},\n  \"full_forward_tok_s\": {full_tok_s:.1},\n  \"decode_vs_full_speedup\": {decode_vs_full:.2},\n  \"paged_fill\": {paged_fill:.3},\n  \"shared_page_ratio\": {shared_page_ratio:.3}\n}}\n",
        dispatch.name(),
        per_thread_ms[0].1,
        per_thread_ms[1].1,
        per_thread_ms[2].1,
        seed_ms / packed_1t_ms,
        packed_1t_ms / packed_4t_ms,
        gops_1t,
        wide44_ms / pair_best_ms,
        e2e_tok_s[0].1,
        e2e_tok_s[1].1,
        decode_tok_s[0],
        decode_tok_s[1],
        decode_tok_s[2],
    );
    let path =
        std::env::var("MUXQ_BENCH_JSON").unwrap_or_else(|_| "BENCH_gemm.json".to_string());
    std::fs::write(&path, json).expect("write bench json");
    println!("\nwrote {path}");
}
