//! GEMM pipeline benchmarks: FP32 reference vs the true-INT pipelines of
//! each method (the deployment-path cost the paper argues about, here on
//! CPU; the NPU projection lives in bench_npusim / npu_latency).
//! Run: `cargo bench --bench bench_gemm`.

use muxq::data::prng::SplitMix64;
use muxq::quant::gemm::{matmul_f32, quant_matmul};
use muxq::quant::llmint8::llmint8_matmul;
use muxq::quant::muxq::{muxq_matmul_int, MuxqParams};
use muxq::quant::{Granularity, MatF32};
use muxq::util::bench::Bencher;

fn mat(rows: usize, cols: usize, seed: u64, outliers: &[usize]) -> MatF32 {
    let mut rng = SplitMix64::new(seed);
    let mut m = MatF32::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
    )
    .unwrap();
    for r in 0..rows {
        for &c in outliers {
            *m.at_mut(r, c) *= 25.0;
        }
    }
    m
}

fn main() {
    let mut b = Bencher::default();
    let p = MuxqParams::default();

    for (m, k, n, label) in [
        (256, 512, 512, "c_fc-like 256x512x512"),
        (1024, 256, 1024, "sim-large c_fc 1024x256x1024"),
    ] {
        Bencher::header(&format!("GEMM pipelines ({label}, 8 outlier cols)"));
        let x = mat(m, k, 1, &[1, 30, 60, 90, 120, 150, 180, 210]);
        let w = mat(k, n, 2, &[]);
        b.bench("fp32_reference", || matmul_f32(&x, &w));
        b.bench("naive_int8 (quant+i8gemm+dequant)", || {
            quant_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol)
        });
        b.bench("muxq_int8 (body+skinny aux)", || {
            muxq_matmul_int(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, &p)
        });
        b.bench("llmint8 (int8 + fp16 outlier path)", || {
            llmint8_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, 6.0)
        });
    }

    let naive = b
        .results
        .iter()
        .find(|r| r.name.starts_with("naive_int8"))
        .unwrap()
        .mean
        .as_secs_f64();
    let muxq = b
        .results
        .iter()
        .find(|r| r.name.starts_with("muxq_int8"))
        .unwrap()
        .mean
        .as_secs_f64();
    println!("\nmuxq INT pipeline overhead vs naive INT (first shape): {:.2}x", muxq / naive);
}
