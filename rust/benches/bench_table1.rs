//! Table-1 end-to-end bench: times the full serving path (tokens ->
//! PJRT quantized eval -> per-seq nll) for each method at IA=8 and IA=6,
//! reporting tokens/s per variant — the throughput companion to
//! `examples/table1.rs` (which reports the perplexities themselves).
//! Run: `cargo bench --bench bench_table1` (needs `make artifacts`).

use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::data::eval_set::EvalSet;
use muxq::util::bench::Bencher;

fn main() {
    let registry = match VariantRegistry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping bench_table1: {e:#}\n(run `make artifacts` first)");
            return;
        }
    };
    let eval = EvalSet::load(&muxq::artifacts_dir(), "valid").expect("eval set");

    let mut b = Bencher::default();
    for model in ["sim-small", "sim-medium", "sim-large"] {
        Bencher::header(&format!("table1 e2e eval ({model}, one 8x128 batch)"));
        let mut rows = Vec::new();
        for tag in ["fp16-pt", "naive-pt", "muxq-pt", "llmint8-pt", "muxq-pv"] {
            let key = VariantKey::eval(model, tag);
            let Some(meta) = registry.meta(&key) else { continue };
            let (batch, seq) = (meta.batch, meta.seq);
            let windows = eval.windows(seq, batch);
            let mut toks = Vec::with_capacity(batch * seq);
            for w in &windows {
                toks.extend_from_slice(w);
            }
            while toks.len() < batch * seq {
                toks.extend_from_slice(&windows[0]);
            }
            let compiled = registry.get(&key).expect("compile variant");
            // warmup happens inside Bencher; first call includes nothing
            // extra since compilation already happened in get()
            let stats = b
                .bench(&format!("{model}/{tag}"), || {
                    compiled.run(&toks, 8.0, 8.0).expect("run")
                })
                .clone();
            let tok_per_s = (batch * seq) as f64 / stats.mean.as_secs_f64();
            rows.push((tag, tok_per_s));
        }
        for (tag, tps) in rows {
            println!("    -> {tag}: {tps:.0} tok/s");
        }
    }
}
