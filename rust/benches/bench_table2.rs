//! Table-2 bench: weight-bit sweep execution cost. Bit-widths are runtime
//! scalars, so this measures that the *same compiled executable* serves
//! W in {8,5,4} with identical latency (no per-bit recompiles — the
//! design decision that makes the Table 2 sweep cheap).
//! Run: `cargo bench --bench bench_table2` (needs `make artifacts`).

use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::data::eval_set::EvalSet;
use muxq::util::bench::Bencher;

fn main() {
    let registry = match VariantRegistry::open_default() {
        Ok(r) => r,
        Err(e) => {
            eprintln!("skipping bench_table2: {e:#}\n(run `make artifacts` first)");
            return;
        }
    };
    let eval = EvalSet::load(&muxq::artifacts_dir(), "valid").expect("eval set");
    let key = VariantKey::eval("sim-small", "muxq-pv");
    let Some(meta) = registry.meta(&key) else {
        eprintln!("muxq-pv variant missing");
        return;
    };
    let (batch, seq) = (meta.batch, meta.seq);
    let windows = eval.windows(seq, batch);
    let mut toks = Vec::with_capacity(batch * seq);
    for w in &windows {
        toks.extend_from_slice(w);
    }
    while toks.len() < batch * seq {
        toks.extend_from_slice(&windows[0]);
    }
    let compiled = registry.get(&key).expect("compile variant");

    let mut b = Bencher::default();
    Bencher::header("table2: one executable, runtime weight-bit sweep (sim-small muxq-pv)");
    let mut means = Vec::new();
    for w_bits in [8.0f32, 5.0, 4.0] {
        let s = b
            .bench(&format!("w_bits={w_bits}"), || {
                compiled.run(&toks, 8.0, w_bits).expect("run")
            })
            .clone();
        means.push(s.mean.as_secs_f64());
    }
    let spread = (means.iter().cloned().fold(f64::MIN, f64::max)
        - means.iter().cloned().fold(f64::MAX, f64::min))
        / means[0];
    println!(
        "\nlatency spread across W bit-widths: {:.1}% (expected ~0: bits are runtime scalars)",
        spread * 100.0
    );
}
