//! Decode-subsystem oracles: the incremental KV-cache path must be
//! BIT-EXACT against a full forward over the same prefix — across ragged
//! prompt lengths, decode depths, cache wrap at `n_ctx`, continuous
//! (multi-session) batching, and both true-INT variants. If any of the
//! ring indexing, position bookkeeping, skinny-GEMV routing or row-wise
//! quantization semantics drifted from the batch path, integer GEMM
//! exactness plus shared f32 primitives would surface it here as an
//! inequality, not an epsilon.

use muxq::gpt2::{
    argmax, decode_step_batch, Gpt2Model, KvCache, QuantizedGpt2, SessionModel, SessionState,
    WrapPolicy,
};
use muxq::quant::EngineSpec;
use muxq::util::proptest::{prop, prop_assert, Gen, PropResult};
use std::collections::VecDeque;

/// Small random model: 1–3 layers, d_head 4–8, n_ctx 8–16, vocab 32.
fn model_for(g: &mut Gen) -> Gpt2Model {
    let n_layer = g.usize(1, 3);
    let n_head = *g.choice(&[1usize, 2, 4]);
    let d_model = n_head * g.usize(4, 8);
    let n_ctx = g.usize(8, 16);
    Gpt2Model::test_model(n_layer, d_model, n_head, n_ctx, 32, g.u64(1, 1 << 30))
}

fn prompt_for(g: &mut Gen, len: usize) -> Vec<u32> {
    (0..len).map(|_| g.usize(0, 31) as u32).collect()
}

fn err_str<T>(r: anyhow::Result<T>) -> Result<T, String> {
    r.map_err(|e| format!("{e:#}"))
}

#[test]
fn prop_fp_decode_bit_exact_vs_full_forward() {
    prop("fp prefill+decode == full forward", |g| {
        let m = model_for(g);
        let n_ctx = m.cfg.n_ctx;
        let plen = g.usize(1, n_ctx - 1);
        let steps = g.usize(1, n_ctx - plen);
        let prompt = prompt_for(g, plen);
        let mut s = m.session(WrapPolicy::default());
        let mut logits = err_str(s.prefill(&prompt))?;
        // prefill returns the last prompt row's logits
        let mut ctx = prompt.clone();
        for step in 0..=steps {
            let full = err_str(m.forward(&[ctx.clone()], None, None))?;
            prop_assert(
                logits[..] == *full.row(ctx.len() - 1),
                format!("len {} step {step}: incremental != full forward", ctx.len()),
            )?;
            if step == steps {
                break;
            }
            let next = argmax(&logits);
            logits = err_str(s.decode_step(next))?;
            ctx.push(next);
        }
        Ok(())
    });
}

#[test]
fn prop_int_decode_bit_exact_vs_session_oracle() {
    // every deployed operator — naive, MUXQ and the new LLM.int8() —
    // sometimes with an injected outlier channel so the per-row masks
    // are genuinely non-empty
    prop("int prefill+decode == rowwise full-forward oracle", |g| {
        let spec = g.choice(&[EngineSpec::naive(), EngineSpec::muxq(), EngineSpec::llmint8()]).clone();
        let mut fp = model_for(g);
        if g.bool() {
            let ch = g.usize(0, fp.cfg.d_model - 1);
            fp.scale_ln1_channel(0, ch, g.f32(8.0, 20.0));
        }
        let ia_bits = *g.choice(&[5u32, 8]);
        let q = QuantizedGpt2::new(fp, spec.with_bits(ia_bits, 8));
        let n_ctx = q.fp.cfg.n_ctx;
        let plen = g.usize(1, n_ctx - 1);
        let steps = g.usize(1, (n_ctx - plen).min(4));
        let prompt = prompt_for(g, plen);
        let mut s = q.session(WrapPolicy::default());
        let mut logits = err_str(s.prefill(&prompt))?;
        let mut ctx = prompt.clone();
        for step in 0..=steps {
            let oracle = err_str(q.forward_logits_session(&[ctx.clone()]))?;
            prop_assert(
                logits[..] == *oracle.row(ctx.len() - 1),
                format!("{} ia{ia_bits} len {} step {step}", q.spec.tag(), ctx.len()),
            )?;
            if step == steps {
                break;
            }
            let next = argmax(&logits);
            logits = err_str(s.decode_step(next))?;
            ctx.push(next);
        }
        Ok(())
    });
}

#[test]
fn prop_wrap_reprefill_exact_past_n_ctx() {
    // generate well past the context window: under the Reprefill policy
    // every step's logits must still equal a full forward over the
    // session's live window — wrap costs latency, never exactness
    prop("reprefill wrap == full forward over live window", |g| {
        let m = model_for(g);
        let n_ctx = m.cfg.n_ctx;
        let keep = g.usize(0, n_ctx - 1); // 0 = policy default (3/4 n_ctx)
        let plen = g.usize(1, n_ctx);
        let steps = n_ctx + g.usize(1, 6); // guaranteed to wrap
        let mut s = m.session(WrapPolicy::Reprefill { keep });
        let mut logits = err_str(s.prefill(&prompt_for(g, plen)))?;
        for step in 0..steps {
            let next = argmax(&logits);
            logits = err_str(s.decode_step(next))?;
            let win = s.state.window().to_vec();
            prop_assert(win.len() <= n_ctx, format!("window {} > n_ctx", win.len()))?;
            let full = err_str(m.forward(&[win.clone()], None, None))?;
            prop_assert(
                logits[..] == *full.row(win.len() - 1),
                format!("keep {keep} step {step} window {}", win.len()),
            )?;
        }
        prop_assert(s.state.prefills() > 1, "must have re-prefilled")
    });
}

#[test]
fn prop_continuous_batch_bit_exact_vs_solo() {
    // G sessions with ragged prompts advanced by coalesced decode steps:
    // every logits row must equal the same session stepped alone — the
    // invariant that makes the generation server's continuous batching
    // transparent to clients
    prop("coalesced decode == solo decode", |g| {
        let use_int = g.bool();
        let fp = model_for(g);
        let cfg = fp.cfg.clone();
        let q;
        let sm = if use_int {
            let spec = g.choice(&[EngineSpec::muxq(), EngineSpec::llmint8()]).clone();
            q = QuantizedGpt2::new(fp, spec);
            SessionModel::Int(&q)
        } else {
            q = QuantizedGpt2::new(fp, EngineSpec::naive()); // fp lives inside
            SessionModel::Fp(&q.fp)
        };
        let n = g.usize(2, 4);
        let prompts: Vec<Vec<u32>> = (0..n)
            .map(|_| {
                let len = g.usize(1, cfg.n_ctx / 2);
                prompt_for(g, len)
            })
            .collect();
        let steps = g.usize(1, 3);
        // solo reference
        let mut solo: Vec<Vec<Vec<f32>>> = Vec::new();
        for p in &prompts {
            let mut st = SessionState::new(&cfg, WrapPolicy::default());
            let mut tok = argmax(&err_str(st.prefill(sm, p))?);
            let mut rows = Vec::new();
            for _ in 0..steps {
                let l = err_str(st.decode_step(sm, tok))?;
                tok = argmax(&l);
                rows.push(l);
            }
            solo.push(rows);
        }
        // coalesced
        let mut states: Vec<SessionState> =
            prompts.iter().map(|_| SessionState::new(&cfg, WrapPolicy::default())).collect();
        let mut tokens: Vec<u32> = Vec::new();
        for (st, p) in states.iter_mut().zip(&prompts) {
            tokens.push(argmax(&err_str(st.prefill(sm, p))?));
        }
        for step in 0..steps {
            let mut refs: Vec<&mut SessionState> = states.iter_mut().collect();
            let batch = err_str(decode_step_batch(sm, &mut refs, &tokens))?;
            for (i, rows) in solo.iter().enumerate() {
                prop_assert(
                    *batch.row(i) == rows[step][..],
                    format!("int={use_int} session {i} step {step}"),
                )?;
            }
            tokens = (0..n).map(|i| argmax(batch.row(i))).collect();
        }
        Ok(())
    });
}

#[test]
fn prop_kv_ring_matches_deque_reference() {
    // the ring buffer against a straightforward VecDeque model: logical
    // order, eviction reporting and wrap-around indexing
    prop("kv ring == deque reference", |g| {
        let cap = g.usize(1, 8);
        let d = g.usize(1, 4);
        let mut ring = KvCache::new(cap, d);
        let mut reference: VecDeque<(Vec<f32>, Vec<f32>)> = VecDeque::new();
        let pushes = g.usize(1, 3 * cap);
        for _ in 0..pushes {
            let k = g.vec_f32(d, -1.0, 1.0);
            let v = g.vec_f32(d, -1.0, 1.0);
            let evicted = ring.push(&k, &v);
            reference.push_back((k, v));
            let should_evict = reference.len() > cap;
            if should_evict {
                reference.pop_front();
            }
            prop_assert(evicted == should_evict, "eviction report")?;
            prop_assert(ring.len() == reference.len(), "length")?;
            check_ring(&ring, &reference)?;
        }
        ring.clear();
        prop_assert(ring.is_empty(), "clear")
    });
}

fn check_ring(ring: &KvCache, reference: &VecDeque<(Vec<f32>, Vec<f32>)>) -> PropResult {
    for (j, (rk, rv)) in reference.iter().enumerate() {
        prop_assert(
            ring.k_row(j) == &rk[..] && ring.v_row(j) == &rv[..],
            format!("logical row {j} mismatch"),
        )?;
    }
    Ok(())
}

#[test]
fn slide_policy_survives_long_generation() {
    // Slide is documented approximate (positions clamp after wrap), so
    // there is no full-forward oracle — pin the operational contract:
    // fixed memory, finite logits, O(1) steps forever, no re-prefills
    let m = Gpt2Model::test_model(2, 16, 2, 10, 32, 99);
    let mut s = m.session(WrapPolicy::Slide);
    let mut logits = s.prefill(&[1, 2, 3, 4, 5, 6, 7]).unwrap();
    for step in 0..40 {
        let next = argmax(&logits);
        logits = s.decode_step(next).unwrap();
        assert!(s.state.context_len() <= 10, "step {step}");
        assert!(logits.iter().all(|v| v.is_finite()), "step {step}");
    }
    assert_eq!(s.state.prefills(), 1);
    assert_eq!(s.state.context_len(), 10);
}
