//! Property-based invariants (via the in-repo mini-proptest): the
//! algebraic guarantees the paper's method rests on, plus coordinator
//! state-machine invariants, across randomized inputs.

use muxq::coordinator::batcher::{BatchKey, Batcher, BatcherConfig};
use muxq::coordinator::request::{Pending, ScoreRequest};
use muxq::coordinator::VariantKey;
use muxq::quant::absmax::{fq_naive, qmax_from_bits, quantize_i8, Granularity, Scales};
use muxq::quant::matrix::{MatI32, MatI8};
use muxq::quant::muxq::{
    decompose, fq_muxq, gather_outlier_cols, gather_outlier_rows, muxq_matmul_int,
    outlier_count, outlier_mask, reconstruct, MuxqParams,
};
use muxq::quant::packed::{
    matmul_i8_gemv_into, matmul_i8_packed_kernel_into, matmul_i8_packed_with,
    matmul_i8_rows_subset_into, Kernel, PackedMatI8, ParallelGemm,
};
use muxq::quant::simd;
use muxq::quant::{gemm, MatF32};
use muxq::util::proptest::{prop, prop_assert, Gen};
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn gen_matrix(g: &mut Gen, max_dim: usize) -> MatF32 {
    let rows = g.usize(1, max_dim);
    let cols = g.usize(1, max_dim);
    let mut m = MatF32::from_vec(rows, cols, g.vec_f32(rows * cols, -4.0, 4.0)).unwrap();
    // sometimes inject outlier columns
    if g.bool() {
        let n_out = g.usize(1, cols.min(4));
        for _ in 0..n_out {
            let c = g.usize(0, cols - 1);
            let scale = g.f32(8.0, 64.0);
            for r in 0..rows {
                *m.at_mut(r, c) *= scale;
            }
        }
    }
    m
}

#[test]
fn prop_muxq_reconstruction_is_exact() {
    prop("muxq reconstruct == identity", |g| {
        let x = gen_matrix(g, 48);
        let exp = g.usize(1, 4) as u32;
        let p = MuxqParams { theta: g.f32(1.0, 10.0), exp_factor: exp };
        let mask = outlier_mask(&x, p.theta);
        let (body, aux) = decompose(&x, &mask, &p);
        let rec = reconstruct(&body, &aux, &p);
        prop_assert(
            rec.max_abs_diff(&x) <= 1e-4 * x.absmax().max(1.0),
            format!("diff {}", rec.max_abs_diff(&x)),
        )
    });
}

#[test]
fn prop_body_absmax_never_exceeds_input() {
    prop("body range <= input range", |g| {
        let x = gen_matrix(g, 48);
        let p = MuxqParams { theta: 6.0, exp_factor: g.usize(1, 4) as u32 };
        let mask = outlier_mask(&x, p.theta);
        let (body, _) = decompose(&x, &mask, &p);
        prop_assert(body.absmax() <= x.absmax() + 1e-6, "body grew")
    });
}

#[test]
fn prop_fake_quant_error_bounded_by_half_step() {
    prop("fq error <= scale/2 per element", |g| {
        let x = gen_matrix(g, 32);
        let bits = *g.choice(&[4u32, 5, 6, 7, 8]);
        let qmax = qmax_from_bits(bits);
        let y = fq_naive(&x, qmax, Granularity::PerTensor);
        let step = x.absmax().max(1e-8) / qmax;
        prop_assert(
            x.max_abs_diff(&y) <= step / 2.0 + 1e-5,
            format!("err {} step {}", x.max_abs_diff(&y), step),
        )
    });
}

#[test]
fn prop_muxq_never_worse_than_naive_per_tensor() {
    prop("muxq <= naive + eps at per-tensor", |g| {
        let x = gen_matrix(g, 48);
        let bits = *g.choice(&[5u32, 6, 7, 8]);
        let qmax = qmax_from_bits(bits);
        let p = MuxqParams::default();
        let e_m = fq_muxq(&x, qmax, Granularity::PerTensor, &p).mean_abs_diff(&x);
        let e_n = fq_naive(&x, qmax, Granularity::PerTensor).mean_abs_diff(&x);
        // without outliers they are identical; with outliers muxq wins.
        // tiny epsilon for boundary cases where theta splits a column
        prop_assert(e_m <= e_n * 1.02 + 1e-6, format!("muxq {e_m} naive {e_n}"))
    });
}

#[test]
fn prop_quant_matmul_scale_factoring_exact() {
    prop("int pipeline == fq(x)@fq(w)", |g| {
        let m = g.usize(1, 24);
        let k = g.usize(1, 24);
        let n = g.usize(1, 24);
        let x = MatF32::from_vec(m, k, g.vec_f32(m * k, -4.0, 4.0)).unwrap();
        let w = MatF32::from_vec(k, n, g.vec_f32(k * n, -2.0, 2.0)).unwrap();
        let qmax = qmax_from_bits(*g.choice(&[4u32, 8]));
        let got = gemm::quant_matmul(&x, &w, qmax, Granularity::PerRow, Granularity::PerCol);
        let fx = fq_naive(&x, qmax, Granularity::PerRow);
        let fw = fq_naive(&w, qmax, Granularity::PerCol);
        let want = gemm::matmul_f32(&fx, &fw);
        let tol = 1e-4 * want.absmax().max(1.0);
        prop_assert(got.max_abs_diff(&want) <= tol, format!("diff {}", got.max_abs_diff(&want)))
    });
}

#[test]
fn prop_scales_positive_and_finite() {
    prop("scales positive/finite incl. zero matrices", |g| {
        let rows = g.usize(1, 16);
        let cols = g.usize(1, 16);
        let data =
            if g.bool() { vec![0.0; rows * cols] } else { g.vec_f32(rows * cols, -1.0, 1.0) };
        let x = MatF32::from_vec(rows, cols, data).unwrap();
        for gran in [Granularity::PerTensor, Granularity::PerRow, Granularity::PerCol] {
            let s = Scales::compute(&x, 127.0, gran);
            for r in 0..rows {
                for c in 0..cols {
                    let v = s.at(r, c);
                    if !(v > 0.0 && v.is_finite()) {
                        return Err(format!("scale {v} at ({r},{c})"));
                    }
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------- packed INT8 engine

fn gen_i8(g: &mut Gen, rows: usize, cols: usize) -> MatI8 {
    let mut m = MatI8::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = (g.usize(0, 254) as i32 - 127) as i8;
    }
    m
}

/// The ground-truth naive triple loop (exact in i32 for i8 operands).
fn matmul_i8_triple(a: &MatI8, b: &MatI8) -> MatI32 {
    let mut c = MatI32::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut acc = 0i32;
            for k in 0..a.cols {
                acc += a.row(i)[k] as i32 * b.data[k * b.cols + j] as i32;
            }
            c.data[i * b.cols + j] = acc;
        }
    }
    c
}

#[test]
fn prop_packed_matmul_bit_exact_vs_triple_loop() {
    prop("packed/parallel i8 GEMM == naive triple loop", |g| {
        let m = g.usize(1, 40);
        let k = g.usize(1, 40);
        let n = g.usize(1, 40);
        let a = gen_i8(g, m, k);
        let b = gen_i8(g, k, n);
        let want = matmul_i8_triple(&a, &b);
        let bp = PackedMatI8::pack(&b);
        let seq = matmul_i8_packed_with(&a, &bp, ParallelGemm::sequential());
        prop_assert(seq.data == want.data, format!("sequential {m}x{k}x{n}"))?;
        let threads = g.usize(2, 6);
        let par =
            matmul_i8_packed_with(&a, &bp, ParallelGemm { threads, min_parallel_macs: 0 });
        prop_assert(par.data == want.data, format!("{threads} threads {m}x{k}x{n}"))
    });
}

#[test]
fn packed_matmul_exact_on_panel_boundary_shapes() {
    // 1x1x1, prime dims, and dims straddling the MR/NR panel boundaries
    for &(m, k, n) in &[
        (1, 1, 1),
        (2, 3, 5),
        (7, 11, 13),
        (3, 4, 4),
        (4, 4, 5),
        (5, 9, 3),
        (6, 65, 7),
        (33, 17, 12),
        (9, 8, 8),
    ] {
        let mut rng = muxq::data::prng::SplitMix64::new((m * 1000 + k * 100 + n) as u64);
        let mut a = MatI8::zeros(m, k);
        let mut b = MatI8::zeros(k, n);
        for v in a.data.iter_mut().chain(b.data.iter_mut()) {
            *v = (rng.next_below(255) as i32 - 127) as i8;
        }
        let want = matmul_i8_triple(&a, &b);
        let bp = PackedMatI8::pack(&b);
        for cfg in [
            ParallelGemm::sequential(),
            ParallelGemm { threads: 3, min_parallel_macs: 0 },
        ] {
            let got = matmul_i8_packed_with(&a, &bp, cfg);
            assert_eq!(got.data, want.data, "{m}x{k}x{n} ({} threads)", cfg.threads);
        }
        // the routed public entry must agree too (blocked or packed path)
        let routed = gemm::matmul_i8(&a, &b);
        assert_eq!(routed.data, want.data, "routed {m}x{k}x{n}");
    }
}

#[test]
fn prop_pair_accum_bit_exact_vs_triple_loop() {
    // the i16 pair-accumulation microkernel vs the naive triple loop,
    // across random shapes (odd and even K), every register tile and
    // both explicit kernels — the overflow-bound pin: if the pair sum
    // could wrap, integer equality would fail
    prop("pair-accum i8 GEMM == naive triple loop", |g| {
        let m = g.usize(1, 40);
        let k = g.usize(1, 48);
        let n = g.usize(1, 40);
        let a = gen_i8(g, m, k);
        let b = gen_i8(g, k, n);
        let want = matmul_i8_triple(&a, &b);
        let nr = *g.choice(&[4usize, 8]);
        let mr = *g.choice(&[4usize, 8]);
        let bp = PackedMatI8::pack_with(&b, nr);
        for kernel in [Kernel::PairI16, Kernel::WideI32] {
            let mut c = MatI32::zeros(0, 0);
            matmul_i8_packed_kernel_into(&a, &bp, &mut c, ParallelGemm::sequential(), kernel, mr);
            prop_assert(
                c.data == want.data,
                format!("{m}x{k}x{n} {kernel:?} tile {mr}x{nr}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_gemv_bit_exact_vs_triple_loop() {
    // the skinny-M decode path (no A interleave, no tile cascade) vs the
    // naive triple loop: random M <= 4, odd/even K, ragged N, both panel
    // widths, occasional -128-laden B (forcing the wide fallback), plus
    // the rows-subset (Aux) GEMV against a random index list
    prop("skinny-M GEMV == naive triple loop", |g| {
        let m = g.usize(1, 4);
        let k = g.usize(1, 48);
        let n = g.usize(1, 24);
        let a = gen_i8(g, m, k);
        let mut b = gen_i8(g, k, n);
        if g.bool() {
            let r = g.usize(0, b.data.len() - 1);
            b.data[r] = i8::MIN; // wide-fallback territory
        }
        let nr = *g.choice(&[4usize, 8]);
        let bp = PackedMatI8::pack_with(&b, nr);
        let want = matmul_i8_triple(&a, &b);
        let mut c = MatI32::zeros(0, 0);
        matmul_i8_gemv_into(&a, &bp, &mut c, Kernel::Auto);
        prop_assert(c.data == want.data, format!("gemv {m}x{k}x{n} nr {nr}"))?;
        // auto-routed entry (takes the GEMV route for M <= 4)
        let routed = matmul_i8_packed_with(&a, &bp, ParallelGemm::sequential());
        prop_assert(routed.data == want.data, format!("routed {m}x{k}x{n}"))?;
        // rows-subset GEMV: compact A against scattered B rows
        let big_rows = g.usize(1, 20);
        let big = gen_i8(g, big_rows, n);
        let r = g.usize(1, big.rows.min(8));
        let idx: Vec<usize> = (0..r).map(|_| g.usize(0, big.rows - 1)).collect();
        let ac = gen_i8(g, m, r);
        let bigp = PackedMatI8::pack_with(&big, nr);
        let mut got = MatI32::zeros(0, 0);
        matmul_i8_rows_subset_into(&ac, &bigp, &idx, &mut got, ParallelGemm::sequential());
        let mut gathered = MatI8::zeros(r, n);
        for (t, &row) in idx.iter().enumerate() {
            gathered.data[t * n..(t + 1) * n].copy_from_slice(big.row(row));
        }
        let want_aux = matmul_i8_triple(&ac, &gathered);
        prop_assert(got.data == want_aux.data, format!("subset gemv m {m} r {r} nr {nr}"))
    });
}

#[test]
fn pair_accum_exact_on_ragged_shape_families() {
    // three ragged families, deterministically: (a) odd K — the pair
    // loop's zero-padded K row; (b) K smaller than one unroll/panel —
    // degenerate contractions; (c) M/N straddling every tile boundary
    let families: [&[(usize, usize, usize)]; 3] = [
        &[(4, 1, 4), (8, 3, 8), (5, 7, 9), (16, 65, 16), (6, 129, 10)], // odd K
        &[(1, 1, 1), (2, 2, 3), (9, 2, 7), (12, 4, 5)],                 // tiny K
        &[(3, 8, 5), (7, 16, 11), (9, 10, 13), (17, 12, 15)],           // M/N tails
    ];
    for (fi, family) in families.iter().enumerate() {
        for &(m, k, n) in family.iter() {
            let mut rng =
                muxq::data::prng::SplitMix64::new((fi * 7919 + m * 131 + k * 17 + n) as u64);
            let mut a = MatI8::zeros(m, k);
            let mut b = MatI8::zeros(k, n);
            for v in a.data.iter_mut().chain(b.data.iter_mut()) {
                *v = (rng.next_below(255) as i32 - 127) as i8;
            }
            let want = matmul_i8_triple(&a, &b);
            for nr in [4usize, 8] {
                let bp = PackedMatI8::pack_with(&b, nr);
                for mr in [4usize, 8] {
                    let mut c = MatI32::zeros(0, 0);
                    matmul_i8_packed_kernel_into(
                        &a,
                        &bp,
                        &mut c,
                        ParallelGemm::sequential(),
                        Kernel::PairI16,
                        mr,
                    );
                    assert_eq!(c.data, want.data, "family {fi} {m}x{k}x{n} tile {mr}x{nr}");
                }
            }
        }
    }
}

#[test]
fn prop_simd_kernels_bit_exact_vs_scalar_oracles() {
    // the per-arch SIMD kernels (AVX2 pmaddwd / NEON sdot-smlal) vs the
    // naive triple loop AND the scalar kernels, across random ragged
    // shapes, the full register-tile grid, and −128-laden B operands —
    // the corner the scalar pair kernel must dodge, which the SIMD
    // kernels (i32 pair/quad sums) must survive bit-exactly. On hosts
    // without a SIMD kernel there is nothing to pin (the CI matrix runs
    // this on x86-64 AND arm64, so both SIMD paths are exercised).
    if simd::host_simd().is_none() {
        return;
    }
    prop("simd i8 GEMM == scalar oracles", |g| {
        let m = g.usize(1, 40);
        let k = g.usize(1, 48);
        let n = g.usize(1, 40);
        let a = gen_i8(g, m, k);
        let mut b = gen_i8(g, k, n);
        if g.bool() {
            // −128 corner: scatter a few true minimums into B
            for _ in 0..g.usize(1, 4) {
                let at = g.usize(0, b.data.len() - 1);
                b.data[at] = i8::MIN;
            }
        }
        let want = matmul_i8_triple(&a, &b);
        let nr = *g.choice(&[4usize, 8]);
        let mr = *g.choice(&[4usize, 8]);
        let bp = PackedMatI8::pack_with(&b, nr);
        let mut c = MatI32::zeros(0, 0);
        matmul_i8_packed_kernel_into(&a, &bp, &mut c, ParallelGemm::sequential(), Kernel::Simd, mr);
        prop_assert(c.data == want.data, format!("simd {m}x{k}x{n} tile {mr}x{nr}"))?;
        // the wide-i32 oracle through the same packed layout agrees too
        let mut w = MatI32::zeros(0, 0);
        matmul_i8_packed_kernel_into(
            &a,
            &bp,
            &mut w,
            ParallelGemm::sequential(),
            Kernel::WideI32,
            mr,
        );
        prop_assert(c.data == w.data, format!("simd vs wide {m}x{k}x{n}"))?;
        // ... and vs the scalar pair kernel where it is eligible
        if !bp.has_neg128() {
            let mut p = MatI32::zeros(0, 0);
            matmul_i8_packed_kernel_into(
                &a,
                &bp,
                &mut p,
                ParallelGemm::sequential(),
                Kernel::PairI16,
                mr,
            );
            prop_assert(c.data == p.data, format!("simd vs pair {m}x{k}x{n}"))?;
        }
        // SIMD GEMV (the decode path: 1-row instances of the kernels)
        let mut gv = MatI32::zeros(0, 0);
        matmul_i8_gemv_into(&a, &bp, &mut gv, Kernel::Simd);
        prop_assert(gv.data == want.data, format!("simd gemv {m}x{k}x{n}"))?;
        // rows-subset (Aux) through whatever Auto resolves under the
        // current env, pinned against the explicit gather
        let r = g.usize(1, k.min(8));
        let idx: Vec<usize> = (0..r).map(|_| g.usize(0, k - 1)).collect();
        let ac = gen_i8(g, m, r);
        let mut got = MatI32::zeros(0, 0);
        matmul_i8_rows_subset_into(&ac, &bp, &idx, &mut got, ParallelGemm::sequential());
        let mut gathered = MatI8::zeros(r, n);
        for (t, &row) in idx.iter().enumerate() {
            gathered.data[t * n..(t + 1) * n].copy_from_slice(b.row(row));
        }
        let want_aux = matmul_i8_triple(&ac, &gathered);
        prop_assert(got.data == want_aux.data, format!("subset m {m} r {r} nr {nr}"))
    });
}

#[test]
fn simd_exact_on_ragged_shape_families_full_tile_grid() {
    // the deterministic twin of the pair-kernel family test: odd K (the
    // quad/pair tails), tiny K (degenerate contractions), M/N straddling
    // every tile boundary — every (mr, nr) combination through the
    // explicit SIMD kernel, plus the all-(−128) worst case per shape
    if simd::host_simd().is_none() {
        return;
    }
    let families: [&[(usize, usize, usize)]; 3] = [
        &[(4, 1, 4), (8, 3, 8), (5, 7, 9), (16, 65, 16), (6, 129, 10)], // odd K
        &[(1, 1, 1), (2, 2, 3), (9, 2, 7), (12, 4, 5)],                 // tiny K
        &[(3, 8, 5), (7, 16, 11), (9, 10, 13), (17, 12, 15)],           // M/N tails
    ];
    for (fi, family) in families.iter().enumerate() {
        for &(m, k, n) in family.iter() {
            let mut rng =
                muxq::data::prng::SplitMix64::new((fi * 7919 + m * 131 + k * 17 + n) as u64);
            let mut a = MatI8::zeros(m, k);
            let mut b = MatI8::zeros(k, n);
            for v in a.data.iter_mut().chain(b.data.iter_mut()) {
                *v = (rng.next_below(255) as i32 - 127) as i8;
            }
            let mut b_min = MatI8::zeros(k, n);
            b_min.data.iter_mut().for_each(|v| *v = i8::MIN);
            for (tag, bmat) in [("rand", &b), ("neg128", &b_min)] {
                let want = matmul_i8_triple(&a, bmat);
                for nr in [4usize, 8] {
                    let bp = PackedMatI8::pack_with(bmat, nr);
                    for mr in [4usize, 8] {
                        let mut c = MatI32::zeros(0, 0);
                        matmul_i8_packed_kernel_into(
                            &a,
                            &bp,
                            &mut c,
                            ParallelGemm::sequential(),
                            Kernel::Simd,
                            mr,
                        );
                        assert_eq!(
                            c.data, want.data,
                            "family {fi} {tag} {m}x{k}x{n} tile {mr}x{nr}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_routed_matmul_i8_bit_exact() {
    // dims large enough to sometimes cross the pack-on-the-fly threshold,
    // so both the blocked fallback and the packed route are exercised
    prop("matmul_i8 routing == naive triple loop", |g| {
        let m = g.usize(1, 48);
        let k = g.usize(1, 64);
        let n = g.usize(1, 48);
        let a = gen_i8(g, m, k);
        let b = gen_i8(g, k, n);
        let got = gemm::matmul_i8(&a, &b);
        let want = matmul_i8_triple(&a, &b);
        prop_assert(got.data == want.data, format!("{m}x{k}x{n}"))
    });
}

#[test]
fn prop_rows_subset_kernel_equals_explicit_gather() {
    prop("idx-mapped aux GEMM == gather + dense GEMM", |g| {
        let m = g.usize(1, 24);
        let kb = g.usize(1, 32);
        let n = g.usize(1, 24);
        let b = gen_i8(g, kb, n);
        // random strictly-increasing row subset (the outlier index list)
        let mut idx = Vec::new();
        for row in 0..kb {
            if g.bool() {
                idx.push(row);
            }
        }
        let a = gen_i8(g, m, idx.len());
        let bp = PackedMatI8::pack(&b);
        let mut got = MatI32::zeros(0, 0);
        matmul_i8_rows_subset_into(&a, &bp, &idx, &mut got, ParallelGemm::sequential());
        let mut gathered = MatI8::zeros(idx.len(), n);
        for (t, &row) in idx.iter().enumerate() {
            gathered.data[t * n..(t + 1) * n].copy_from_slice(b.row(row));
        }
        let want = matmul_i8_triple(&a, &gathered);
        prop_assert(got.data == want.data, format!("m={m} r={} n={n}", idx.len()))
    });
}

/// Literal transcription of the seed `muxq_matmul_int` (full gather of
/// outlier weight rows, full-W per-col scale recomputation and all) —
/// the before-side oracle guarding the zero-copy refactor.
fn muxq_matmul_int_seed_reference(
    x: &MatF32,
    w: &MatF32,
    qmax: f32,
    gx: Granularity,
    gw: Granularity,
    p: &MuxqParams,
) -> MatF32 {
    let mask = outlier_mask(x, p.theta);
    let (body, _) = decompose(x, &mask, p);
    let sb = Scales::compute(&body, qmax, gx);
    let sw = Scales::compute(w, qmax, gw);
    let bq = quantize_i8(&body, &sb, qmax);
    let wq = quantize_i8(w, &sw, qmax);
    let mut y = gemm::dequant(&matmul_i8_triple(&bq, &wq), &sb, &sw);
    let r = outlier_count(&mask);
    if r > 0 {
        let aux = gather_outlier_cols(x, &mask, p.inv_shift());
        let w_out = gather_outlier_rows(w, &mask);
        let sa = Scales::compute(&aux, qmax, gx);
        let swo = match gw {
            Granularity::PerCol => Scales::compute(w, qmax, Granularity::PerCol),
            _ => Scales::compute(&w_out, qmax, gw),
        };
        let aq = quantize_i8(&aux, &sa, qmax);
        let woq = quantize_i8(&w_out, &swo, qmax);
        let ya = gemm::dequant(&matmul_i8_triple(&aq, &woq), &sa, &swo);
        let f = p.aux_weight();
        for (yv, av) in y.data.iter_mut().zip(&ya.data) {
            *yv += f * av;
        }
    }
    y
}

#[test]
fn prop_muxq_matmul_int_unchanged_by_refactor() {
    prop("muxq_matmul_int == seed reference", |g| {
        let x = gen_matrix(g, 40);
        let n = g.usize(1, 24);
        let w = MatF32::from_vec(x.cols, n, g.vec_f32(x.cols * n, -2.0, 2.0)).unwrap();
        let qmax = qmax_from_bits(*g.choice(&[5u32, 8]));
        let p = MuxqParams { theta: 6.0, exp_factor: g.usize(1, 3) as u32 };
        let gx = *g.choice(&[Granularity::PerRow, Granularity::PerTensor]);
        let gw = *g.choice(&[Granularity::PerCol, Granularity::PerTensor]);
        let got = muxq_matmul_int(&x, &w, qmax, gx, gw, &p);
        let want = muxq_matmul_int_seed_reference(&x, &w, qmax, gx, gw, &p);
        let tol = 1e-6 * want.absmax().max(1.0);
        prop_assert(
            got.max_abs_diff(&want) <= tol,
            format!("diff {} tol {tol}", got.max_abs_diff(&want)),
        )
    });
}

#[test]
fn rerouted_muxq_percol_bit_exact_with_scattered_outliers() {
    // the PerCol zero-copy reroute (pack W once, aux reads outlier rows
    // out of the packed layout) must be BIT-exact vs the seed-reference
    // gather formulation: integer GEMMs are exact and the dequant /
    // recombination run the identical f32 op sequence. Exercise
    // deliberately non-contiguous outlier index sets, including
    // odd-cardinality ones (the pair kernel's index-tail step). The
    // 32x36x120 shape clears the pack-amortization bar (m >= 16,
    // m*k*n >= 2^17), so the packed route — not the gather fallback —
    // is what runs.
    let p = MuxqParams::default();
    for (seed, out_cols) in [
        (11u64, &[0usize, 5, 6, 19][..]),    // first column + a run + a stray
        (12, &[3, 17, 18, 22, 29][..]),      // odd cardinality
        (13, &[35][..]),                     // single outlier, last column
        (14, &[1, 2, 3, 4, 5, 6, 7, 8][..]), // dense block
    ] {
        let mut rng = muxq::data::prng::SplitMix64::new(seed);
        let mut x = MatF32::from_vec(
            32,
            36,
            (0..32 * 36).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
        )
        .unwrap();
        for r in 0..x.rows {
            for &c in out_cols {
                *x.at_mut(r, c) *= 20.0;
            }
        }
        let w = MatF32::from_vec(
            36,
            120,
            (0..36 * 120).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
        )
        .unwrap();
        let mask = outlier_mask(&x, p.theta);
        for &c in out_cols {
            assert!(mask[c], "outlier injection failed at col {c}");
        }
        let got =
            muxq_matmul_int(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, &p);
        let want = muxq_matmul_int_seed_reference(
            &x,
            &w,
            127.0,
            Granularity::PerRow,
            Granularity::PerCol,
            &p,
        );
        assert_eq!(got.data, want.data, "seed {seed}: reroute must be bit-exact");
    }
}

// ------------------------------------------------------------ batcher
fn mk_pending(variant: &VariantKey, seq: usize, ia_bits: f32) -> Pending {
    let (tx, _rx) = mpsc::channel();
    Pending {
        req: ScoreRequest { variant: variant.clone(), tokens: vec![0; seq], ia_bits, w_bits: 8.0 },
        submitted: Instant::now(),
        tx,
    }
}

#[test]
fn prop_batcher_conserves_requests() {
    prop("batcher neither loses nor duplicates", |g| {
        let max_batch = g.usize(1, 8);
        let batcher = Batcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(0), // everything immediately due
            max_queue: 10_000,
        });
        let variants = ["a", "b", "c"];
        let n = g.usize(1, 60);
        let mut pushed_per_key = std::collections::BTreeMap::new();
        for _ in 0..n {
            let v = VariantKey::eval("m", *g.choice(&variants));
            let bits = *g.choice(&[6.0f32, 8.0]);
            let key = BatchKey::of(&v, bits, 8.0);
            batcher.push(key.clone(), mk_pending(&v, 4, bits)).unwrap();
            *pushed_per_key.entry(key).or_insert(0usize) += 1;
        }
        let mut popped_per_key = std::collections::BTreeMap::new();
        while batcher.queued() > 0 {
            let batch = batcher.next_batch().unwrap();
            prop_assert(batch.requests.len() <= max_batch, "batch too large")?;
            prop_assert(!batch.requests.is_empty(), "empty batch")?;
            // batch homogeneity: all requests share the key
            for p in &batch.requests {
                let k = BatchKey::of(&p.req.variant, p.req.ia_bits, p.req.w_bits);
                prop_assert(k == batch.key, "mixed batch")?;
            }
            *popped_per_key.entry(batch.key.clone()).or_insert(0usize) += batch.requests.len();
        }
        prop_assert(pushed_per_key == popped_per_key, "conservation violated")
    });
}

#[test]
fn prop_batcher_respects_capacity() {
    prop("admission control enforces max_queue", |g| {
        let cap = g.usize(1, 16);
        let batcher = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
            max_queue: cap,
        });
        let v = VariantKey::eval("m", "t");
        let key = BatchKey::of(&v, 8.0, 8.0);
        let mut accepted = 0;
        for _ in 0..cap + 5 {
            if batcher.push(key.clone(), mk_pending(&v, 4, 8.0)).is_ok() {
                accepted += 1;
            }
        }
        prop_assert(accepted == cap, format!("accepted {accepted} != cap {cap}"))
    });
}
