//! Property-based invariants (via the in-repo mini-proptest): the
//! algebraic guarantees the paper's method rests on, plus coordinator
//! state-machine invariants, across randomized inputs.

use muxq::coordinator::batcher::{BatchKey, Batcher, BatcherConfig};
use muxq::coordinator::request::{Pending, ScoreRequest};
use muxq::coordinator::VariantKey;
use muxq::quant::absmax::{fq_naive, qmax_from_bits, Granularity, Scales};
use muxq::quant::muxq::{decompose, fq_muxq, outlier_mask, reconstruct, MuxqParams};
use muxq::quant::{gemm, MatF32};
use muxq::util::proptest::{prop, prop_assert, Gen};
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn gen_matrix(g: &mut Gen, max_dim: usize) -> MatF32 {
    let rows = g.usize(1, max_dim);
    let cols = g.usize(1, max_dim);
    let mut m = MatF32::from_vec(rows, cols, g.vec_f32(rows * cols, -4.0, 4.0)).unwrap();
    // sometimes inject outlier columns
    if g.bool() {
        let n_out = g.usize(1, cols.min(4));
        for _ in 0..n_out {
            let c = g.usize(0, cols - 1);
            let scale = g.f32(8.0, 64.0);
            for r in 0..rows {
                *m.at_mut(r, c) *= scale;
            }
        }
    }
    m
}

#[test]
fn prop_muxq_reconstruction_is_exact() {
    prop("muxq reconstruct == identity", |g| {
        let x = gen_matrix(g, 48);
        let exp = g.usize(1, 4) as u32;
        let p = MuxqParams { theta: g.f32(1.0, 10.0), exp_factor: exp };
        let mask = outlier_mask(&x, p.theta);
        let (body, aux) = decompose(&x, &mask, &p);
        let rec = reconstruct(&body, &aux, &p);
        prop_assert(
            rec.max_abs_diff(&x) <= 1e-4 * x.absmax().max(1.0),
            format!("diff {}", rec.max_abs_diff(&x)),
        )
    });
}

#[test]
fn prop_body_absmax_never_exceeds_input() {
    prop("body range <= input range", |g| {
        let x = gen_matrix(g, 48);
        let p = MuxqParams { theta: 6.0, exp_factor: g.usize(1, 4) as u32 };
        let mask = outlier_mask(&x, p.theta);
        let (body, _) = decompose(&x, &mask, &p);
        prop_assert(body.absmax() <= x.absmax() + 1e-6, "body grew")
    });
}

#[test]
fn prop_fake_quant_error_bounded_by_half_step() {
    prop("fq error <= scale/2 per element", |g| {
        let x = gen_matrix(g, 32);
        let bits = *g.choice(&[4u32, 5, 6, 7, 8]);
        let qmax = qmax_from_bits(bits);
        let y = fq_naive(&x, qmax, Granularity::PerTensor);
        let step = x.absmax().max(1e-8) / qmax;
        prop_assert(
            x.max_abs_diff(&y) <= step / 2.0 + 1e-5,
            format!("err {} step {}", x.max_abs_diff(&y), step),
        )
    });
}

#[test]
fn prop_muxq_never_worse_than_naive_per_tensor() {
    prop("muxq <= naive + eps at per-tensor", |g| {
        let x = gen_matrix(g, 48);
        let bits = *g.choice(&[5u32, 6, 7, 8]);
        let qmax = qmax_from_bits(bits);
        let p = MuxqParams::default();
        let e_m = fq_muxq(&x, qmax, Granularity::PerTensor, &p).mean_abs_diff(&x);
        let e_n = fq_naive(&x, qmax, Granularity::PerTensor).mean_abs_diff(&x);
        // without outliers they are identical; with outliers muxq wins.
        // tiny epsilon for boundary cases where theta splits a column
        prop_assert(e_m <= e_n * 1.02 + 1e-6, format!("muxq {e_m} naive {e_n}"))
    });
}

#[test]
fn prop_quant_matmul_scale_factoring_exact() {
    prop("int pipeline == fq(x)@fq(w)", |g| {
        let m = g.usize(1, 24);
        let k = g.usize(1, 24);
        let n = g.usize(1, 24);
        let x = MatF32::from_vec(m, k, g.vec_f32(m * k, -4.0, 4.0)).unwrap();
        let w = MatF32::from_vec(k, n, g.vec_f32(k * n, -2.0, 2.0)).unwrap();
        let qmax = qmax_from_bits(*g.choice(&[4u32, 8]));
        let got = gemm::quant_matmul(&x, &w, qmax, Granularity::PerRow, Granularity::PerCol);
        let fx = fq_naive(&x, qmax, Granularity::PerRow);
        let fw = fq_naive(&w, qmax, Granularity::PerCol);
        let want = gemm::matmul_f32(&fx, &fw);
        let tol = 1e-4 * want.absmax().max(1.0);
        prop_assert(got.max_abs_diff(&want) <= tol, format!("diff {}", got.max_abs_diff(&want)))
    });
}

#[test]
fn prop_scales_positive_and_finite() {
    prop("scales positive/finite incl. zero matrices", |g| {
        let rows = g.usize(1, 16);
        let cols = g.usize(1, 16);
        let data = if g.bool() { vec![0.0; rows * cols] } else { g.vec_f32(rows * cols, -1.0, 1.0) };
        let x = MatF32::from_vec(rows, cols, data).unwrap();
        for gran in [Granularity::PerTensor, Granularity::PerRow, Granularity::PerCol] {
            let s = Scales::compute(&x, 127.0, gran);
            for r in 0..rows {
                for c in 0..cols {
                    let v = s.at(r, c);
                    if !(v > 0.0 && v.is_finite()) {
                        return Err(format!("scale {v} at ({r},{c})"));
                    }
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------ batcher
fn mk_pending(variant: &VariantKey, seq: usize, ia_bits: f32) -> Pending {
    let (tx, _rx) = mpsc::channel();
    Pending {
        req: ScoreRequest { variant: variant.clone(), tokens: vec![0; seq], ia_bits, w_bits: 8.0 },
        submitted: Instant::now(),
        tx,
    }
}

#[test]
fn prop_batcher_conserves_requests() {
    prop("batcher neither loses nor duplicates", |g| {
        let max_batch = g.usize(1, 8);
        let batcher = Batcher::new(BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(0), // everything immediately due
            max_queue: 10_000,
        });
        let variants = ["a", "b", "c"];
        let n = g.usize(1, 60);
        let mut pushed_per_key = std::collections::BTreeMap::new();
        for _ in 0..n {
            let v = VariantKey::eval("m", *g.choice(&variants));
            let bits = *g.choice(&[6.0f32, 8.0]);
            let key = BatchKey::of(&v, bits, 8.0);
            batcher.push(key.clone(), mk_pending(&v, 4, bits)).unwrap();
            *pushed_per_key.entry(key).or_insert(0usize) += 1;
        }
        let mut popped_per_key = std::collections::BTreeMap::new();
        while batcher.queued() > 0 {
            let batch = batcher.next_batch().unwrap();
            prop_assert(batch.requests.len() <= max_batch, "batch too large")?;
            prop_assert(!batch.requests.is_empty(), "empty batch")?;
            // batch homogeneity: all requests share the key
            for p in &batch.requests {
                let k = BatchKey::of(&p.req.variant, p.req.ia_bits, p.req.w_bits);
                prop_assert(k == batch.key, "mixed batch")?;
            }
            *popped_per_key.entry(batch.key.clone()).or_insert(0usize) += batch.requests.len();
        }
        prop_assert(pushed_per_key == popped_per_key, "conservation violated")
    });
}

#[test]
fn prop_batcher_respects_capacity() {
    prop("admission control enforces max_queue", |g| {
        let cap = g.usize(1, 16);
        let batcher = Batcher::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(10),
            max_queue: cap,
        });
        let v = VariantKey::eval("m", "t");
        let key = BatchKey::of(&v, 8.0, 8.0);
        let mut accepted = 0;
        for _ in 0..cap + 5 {
            if batcher.push(key.clone(), mk_pending(&v, 4, 8.0)).is_ok() {
                accepted += 1;
            }
        }
        prop_assert(accepted == cap, format!("accepted {accepted} != cap {cap}"))
    });
}
