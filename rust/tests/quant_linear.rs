//! Trait-vs-legacy equivalence: the operator redesign must not move a
//! single bit on the paths the repo already trusted. The Naive and MUXQ
//! [`QuantLinear`] operators are pinned BIT-EXACT against oracles
//! reconstructed from the public quantization primitives exactly the way
//! the pre-redesign `QuantizedGpt2::proj_int` composed them (per-row
//! scales → i8 grid → integer GEMM → `acc·(sx·sw) [+ f·aux] + bias`);
//! the new deployed LLM.int8() operator is tolerance-tested against the
//! `llmint8_matmul` fake-quant oracle (it packs W once with full-W
//! scales; the oracle re-quantizes per call with outlier rows zeroed, so
//! bit-equality is not the contract there). Integer GEMM exactness means
//! any drift in mask logic, fused quantization, scale handling or
//! recombination order shows up as an inequality, not an epsilon.

use muxq::data::prng::SplitMix64;
use muxq::quant::absmax::{quantize_i8, Scales, EPS};
use muxq::quant::gemm::matmul_f32;
use muxq::quant::llmint8::llmint8_matmul;
use muxq::quant::muxq::{decompose, gather_outlier_cols, outlier_mask, MuxqParams};
use muxq::quant::{EngineSpec, Granularity, MatF32, MatI8, Method, QuantLinear};
use muxq::util::proptest::{prop, prop_assert, Gen};

fn rand_mat(g: &mut Gen, rows: usize, cols: usize, scale: f32) -> MatF32 {
    MatF32::from_vec(rows, cols, g.vec_f32(rows * cols, -scale, scale)).unwrap()
}

/// Inject a few guaranteed outlier channels (past any theta we draw).
fn spike(g: &mut Gen, x: &mut MatF32, count: usize) {
    for _ in 0..count {
        let c = g.usize(0, x.cols - 1);
        let r = g.usize(0, x.rows - 1);
        *x.at_mut(r, c) = g.f32(15.0, 40.0) * if g.bool() { 1.0 } else { -1.0 };
    }
}

/// Exact i32 GEMM over explicit operands — the oracle contraction
/// (integer arithmetic has one answer; kernel choice cannot matter).
fn gemm_i32(a: &MatI8, b: &MatI8) -> Vec<i32> {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    assert_eq!(k, b.rows);
    let mut c = vec![0i32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a.row(i)[kk] as i32;
            for j in 0..n {
                c[i * n + j] += av * b.data[kk * n + j] as i32;
            }
        }
    }
    c
}

fn qmax(bits: u32) -> f32 {
    muxq::quant::qmax_from_bits(bits)
}

#[test]
fn prop_naive_linear_bit_exact_vs_legacy_oracle() {
    // the legacy pipeline: per-row activation scales + per-col weight
    // scales on the i8 grid, integer GEMM, dequant+bias — reconstructed
    // here from public primitives, compared bit-for-bit
    prop("NaiveLinear == legacy proj_int arithmetic", |g| {
        let (m, k, n) = (g.usize(1, 12), g.usize(1, 24), g.usize(1, 16));
        let ia_bits = *g.choice(&[5u32, 8]);
        let x = rand_mat(g, m, k, 4.0);
        let w = rand_mat(g, k, n, 2.0);
        let bias: Vec<f32> = g.vec_f32(n, -1.0, 1.0);
        let op = EngineSpec::naive().with_bits(ia_bits, 8).pack(&w, &bias);
        let got = op.forward(&x);

        let sx = Scales::compute(&x, qmax(ia_bits), Granularity::PerRow);
        let sw = Scales::compute(&w, qmax(8), Granularity::PerCol);
        let xq = quantize_i8(&x, &sx, qmax(ia_bits));
        let wq = quantize_i8(&w, &sw, qmax(8));
        let acc = gemm_i32(&xq, &wq);
        for r in 0..m {
            for j in 0..n {
                let want = acc[r * n + j] as f32 * (sx.at(r, 0) * sw.at(0, j)) + bias[j];
                prop_assert(
                    got.at(r, j) == want,
                    format!("({r},{j}): got {} want {want}", got.at(r, j)),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_muxq_linear_bit_exact_vs_legacy_oracle() {
    // the full MUXQ two-GEMM pipeline — batch mask, decompose, per-row
    // Body/Aux scales, both integer GEMMs, (2^e − 1) recombination and
    // bias — rebuilt step by step from the public primitives with the
    // same float grouping `acc·(sx·sw) + f·(aux·(sa·sw)) + bias`
    prop("MuxqLinear == legacy two-GEMM arithmetic", |g| {
        let (m, k, n) = (g.usize(1, 10), g.usize(2, 24), g.usize(1, 16));
        let ia_bits = *g.choice(&[5u32, 8]);
        let p = MuxqParams { theta: g.f32(4.0, 8.0), exp_factor: g.usize(1, 3) as u32 };
        let mut x = rand_mat(g, m, k, 4.0);
        if g.bool() {
            let spikes = g.usize(1, 3);
            spike(g, &mut x, spikes);
        }
        let w = rand_mat(g, k, n, 2.0);
        let bias: Vec<f32> = g.vec_f32(n, -1.0, 1.0);
        let op = EngineSpec::muxq().with_bits(ia_bits, 8).with_muxq(p).pack(&w, &bias);
        let got = op.forward(&x);

        let mask = outlier_mask(&x, p.theta);
        let (body, _) = decompose(&x, &mask, &p);
        let sb = Scales::compute(&body, qmax(ia_bits), Granularity::PerRow);
        let sw = Scales::compute(&w, qmax(8), Granularity::PerCol);
        let bq = quantize_i8(&body, &sb, qmax(ia_bits));
        let wq = quantize_i8(&w, &sw, qmax(8));
        let acc = gemm_i32(&bq, &wq);
        let idx: Vec<usize> =
            mask.iter().enumerate().filter(|(_, m)| **m).map(|(i, _)| i).collect();
        if idx.is_empty() {
            for r in 0..m {
                for j in 0..n {
                    let want = acc[r * n + j] as f32 * (sb.at(r, 0) * sw.at(0, j)) + bias[j];
                    prop_assert(got.at(r, j) == want, format!("no-aux ({r},{j})"))?;
                }
            }
            return Ok(());
        }
        // compact Aux against the outlier ROWS of the full quantized W —
        // per-col scales make subset-of-quantized == quantize-of-subset
        let aux = gather_outlier_cols(&x, &mask, p.inv_shift());
        let sa = Scales::compute(&aux, qmax(ia_bits), Granularity::PerRow);
        let aq = quantize_i8(&aux, &sa, qmax(ia_bits));
        let mut wq_rows = MatI8::zeros(idx.len(), n);
        for (t, &kk) in idx.iter().enumerate() {
            let src = &wq.data[kk * n..(kk + 1) * n];
            wq_rows.data[t * n..(t + 1) * n].copy_from_slice(src);
        }
        let acc_aux = gemm_i32(&aq, &wq_rows);
        let f = p.aux_weight();
        for r in 0..m {
            for j in 0..n {
                let swj = sw.at(0, j);
                let want = acc[r * n + j] as f32 * (sb.at(r, 0) * swj)
                    + f * (acc_aux[r * n + j] as f32 * (sa.at(r, 0) * swj))
                    + bias[j];
                prop_assert(
                    got.at(r, j) == want,
                    format!(
                        "exp {} theta {} ({r},{j}): got {} want {want}",
                        p.exp_factor,
                        p.theta,
                        got.at(r, j)
                    ),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_llmint8_linear_tracks_fake_quant_oracle() {
    // deployed llm.int8() vs the per-call fake-quant oracle: same
    // activation treatment, same FP outlier leg; only the weight scales
    // differ (full-W vs outlier-rows-zeroed) — a quantization-step-sized
    // gap, never a structural one
    prop("LlmInt8Linear ~ llmint8_matmul", |g| {
        let (m, k, n) = (g.usize(2, 12), g.usize(8, 32), g.usize(2, 16));
        let mut x = rand_mat(g, m, k, 4.0);
        let spikes = g.usize(1, 3);
        spike(g, &mut x, spikes);
        let w = rand_mat(g, k, n, 2.0);
        let op = EngineSpec::llmint8().pack(&w, &vec![0.0; n]);
        let got = op.forward(&x);
        let oracle =
            llmint8_matmul(&x, &w, 127.0, Granularity::PerRow, Granularity::PerCol, 6.0);
        let exact = matmul_f32(&x, &w);
        let d_oracle = got.mean_abs_diff(&oracle);
        let d_exact = got.mean_abs_diff(&exact);
        // activation quantization is identical on both sides, so the
        // oracle gap (weight scales only) must be far inside the
        // quantization-noise distance to exact FP
        prop_assert(d_oracle < 0.1, format!("vs oracle mae {d_oracle}"))?;
        prop_assert(d_exact < 0.25, format!("vs exact mae {d_exact}"))?;
        Ok(())
    });
}

#[test]
fn prop_row_path_is_single_row_batch_every_method() {
    // the seam the decode bit-exactness oracles stand on: for ONE row,
    // forward_row_into must equal forward_into bit for bit — for every
    // method, under any pre-transform pipeline (smooth / rotate /
    // permute compositions included)
    prop("forward_row_into == 1-row forward_into", |g| {
        let (k, n) = (g.usize(2, 24), g.usize(1, 16));
        let mut x = rand_mat(g, 1, k, 4.0);
        if g.bool() {
            spike(g, &mut x, 1);
        }
        let w = rand_mat(g, k, n, 2.0);
        let bias: Vec<f32> = g.vec_f32(n, -1.0, 1.0);
        let base = [
            EngineSpec::fp16(),
            EngineSpec::naive(),
            EngineSpec::muxq(),
            EngineSpec::llmint8(),
            EngineSpec::resq(),
        ];
        let mut spec = g.choice(&base).clone();
        for _ in 0..g.usize(0, 3) {
            spec = match g.usize(0, 2) {
                0 => spec.with_smooth(0.5),
                1 => spec.with_rotate(),
                _ => spec.with_permute(),
            };
        }
        let op = spec.pack(&w, &bias);
        let batch = op.forward(&x);
        let mut row = vec![0.0f32; n];
        op.forward_row_into(x.row(0), &mut row);
        prop_assert(batch.data == row, format!("{} diverged", spec.tag()))
    });
}

#[test]
fn prop_engine_tag_round_trips() {
    // the FULL extended grammar: method × granularity × an arbitrary
    // ordered pre-transform pipeline (duplicates allowed — order and
    // multiplicity are observable) × resq rank × muxq exp × bit widths
    prop("EngineSpec tag -> parse -> tag is identity", |g| {
        let method = *g.choice(&[
            Method::Fp16,
            Method::Naive,
            Method::Muxq,
            Method::LlmInt8,
            Method::Resq,
        ]);
        let mut spec = EngineSpec::new(method);
        if g.bool() {
            spec = spec.with_granularity(Granularity::PerTensor, Granularity::PerTensor);
        }
        for _ in 0..g.usize(0, 3) {
            spec = match g.usize(0, 2) {
                0 => spec.with_smooth(0.5),
                1 => spec.with_rotate(),
                _ => spec.with_permute(),
            };
        }
        if method == Method::Resq && g.bool() {
            spec = spec.with_resid_rank(g.usize(1, 64));
        }
        if method == Method::Muxq {
            spec = spec.with_muxq(MuxqParams {
                theta: 6.0,
                exp_factor: g.usize(1, 4) as u32,
            });
        }
        if matches!(method, Method::Naive | Method::Muxq) && g.bool() {
            spec = spec.with_bits(8, 4);
        }
        let tag = spec.tag();
        let back = EngineSpec::parse(&tag).map_err(|e| format!("{e:#}"))?;
        prop_assert(back.tag() == tag, format!("{tag} -> {}", back.tag()))?;
        prop_assert(back.method == spec.method, "method survived")?;
        prop_assert(back.pre == spec.pre, format!("{tag}: pipeline survived in order"))?;
        prop_assert(back.resid_rank == spec.resid_rank, "resid rank survived")?;
        prop_assert(
            (back.ia_bits, back.w_bits) == (spec.ia_bits, spec.w_bits),
            "bits survived",
        )?;
        if method == Method::Muxq {
            prop_assert(back.muxq.exp_factor == spec.muxq.exp_factor, "exp survived")?;
        }
        Ok(())
    });
}

#[test]
fn tag_grammar_order_and_rejections() {
    // pipeline order is observable, so the tag spells it: -sq-rot and
    // -rot-sq are DIFFERENT specs that both round-trip
    use muxq::quant::PreTransform;
    let sq_rot = EngineSpec::parse("muxq-pv-sq-rot").unwrap();
    let rot_sq = EngineSpec::parse("muxq-pv-rot-sq").unwrap();
    assert_eq!(sq_rot.tag(), "muxq-pv-sq-rot");
    assert_eq!(rot_sq.tag(), "muxq-pv-rot-sq");
    assert!(matches!(sq_rot.pre[0], PreTransform::Smooth { .. }));
    assert!(matches!(sq_rot.pre[1], PreTransform::Rotate { .. }));
    assert!(matches!(rot_sq.pre[0], PreTransform::Rotate { .. }));
    assert!(matches!(rot_sq.pre[1], PreTransform::Smooth { .. }));
    assert_ne!(sq_rot.pre, rot_sq.pre);

    // the composed W4A8 spelling from the issue round-trips too
    let t = "naive-pv-rot-perm-w4a8";
    assert_eq!(EngineSpec::parse(t).unwrap().tag(), t);
    let t2 = "resq-pv-sq-r8";
    assert_eq!(EngineSpec::parse(t2).unwrap().tag(), t2);

    // rank suffix is resq-only, and rank 0 is meaningless
    assert!(EngineSpec::parse("naive-pv-r4").is_err(), "rank is resq-only");
    assert!(EngineSpec::parse("muxq-pv-r4").is_err(), "rank is resq-only");
    assert!(EngineSpec::parse("resq-pv-r0").is_err(), "rank 0 rejected");
    // junk suffixes still rejected
    assert!(EngineSpec::parse("muxq-pv-rotate").is_err());
    assert!(EngineSpec::parse("muxq-pv-rot-huh").is_err());
}

#[test]
fn naive_per_tensor_matches_oracle_too() {
    // the per-tensor deployment point (the paper's `-pt` rows): one
    // shared activation scale, still bit-exact vs the primitive pipeline
    let mut g_x = SplitMix64::new(404);
    let x = MatF32::from_vec(
        6,
        20,
        (0..120).map(|_| (g_x.next_f64() as f32 - 0.5) * 8.0).collect(),
    )
    .unwrap();
    let w = MatF32::from_vec(
        20,
        10,
        (0..200).map(|_| (g_x.next_f64() as f32 - 0.5) * 2.0).collect(),
    )
    .unwrap();
    let op = EngineSpec::naive()
        .with_granularity(Granularity::PerTensor, Granularity::PerTensor)
        .pack(&w, &vec![0.0; 10]);
    let got = op.forward(&x);
    let sx = Scales::compute(&x, 127.0, Granularity::PerTensor);
    let sw = Scales::compute(&w, 127.0, Granularity::PerTensor);
    let xq = quantize_i8(&x, &sx, 127.0);
    let wq = quantize_i8(&w, &sw, 127.0);
    let acc = gemm_i32(&xq, &wq);
    for r in 0..6 {
        for j in 0..10 {
            let want = acc[r * 10 + j] as f32 * (sx.at(r, 0) * sw.at(0, j)) + 0.0;
            assert_eq!(got.at(r, j), want, "({r},{j})");
        }
    }
    // the shared scale really is the tensor abs-max floor
    let amax = x.absmax();
    assert_eq!(sx.at(0, 0), amax.max(EPS) / 127.0);
}
