//! Differential layer for the paged KV subsystem: a pool-backed session
//! is a STORAGE change, never a results change. Every prop here runs the
//! same token schedule through a ring-backed session (the oracle) and a
//! paged session drawing from a [`KvPool`], and demands bit-equality —
//! logits, windows, and raw K/V rows. K/V rows are deterministic
//! functions of the causal token prefix, so `==` is the right
//! comparison; an epsilon would hide an aliased or stale page.
//!
//! Coverage, per the paged-KV issue: ragged prompts across all engines
//! (fp / naive / muxq / llmint8), Reprefill wrap past `n_ctx`, Slide
//! overwrite on shared storage, speculative `truncate_to` rollback, and
//! shared-prefix accounting (occupancy + isolation) at both the session
//! and the server level.

use muxq::coordinator::{GenBackend, GenerateRequest, GenerationConfig, GenerationServer};
use muxq::gpt2::{
    argmax, DraftKind, DraftModel, Gpt2Model, KvPool, PrefixCache, QuantizedGpt2, Sampler,
    SessionModel, SessionState, SpeculativeState, WrapPolicy,
};
use muxq::quant::EngineSpec;
use muxq::util::proptest::{prop, prop_assert, Gen};

/// Small random model: 1–3 layers, d_head 4–8, n_ctx 8–16, vocab 32.
fn model_for(g: &mut Gen) -> Gpt2Model {
    let n_layer = g.usize(1, 3);
    let n_head = *g.choice(&[1usize, 2, 4]);
    let d_model = n_head * g.usize(4, 8);
    let n_ctx = g.usize(8, 16);
    Gpt2Model::test_model(n_layer, d_model, n_head, n_ctx, 32, g.u64(1, 1 << 30))
}

fn prompt_for(g: &mut Gen, len: usize) -> Vec<u32> {
    (0..len).map(|_| g.usize(0, 31) as u32).collect()
}

fn err_str<T>(r: anyhow::Result<T>) -> Result<T, String> {
    r.map_err(|e| format!("{e:#}"))
}

/// A pool big enough that exhaustion never interferes with a
/// bit-exactness prop (pressure behaviour has its own tests); page size
/// is the interesting knob, so it ranges over ragged vs aligned splits.
fn pool_for(g: &mut Gen, d_model: usize) -> KvPool {
    KvPool::new(256, g.usize(1, 8), d_model)
}

/// Every K/V row the two sessions hold must be bit-identical, layer by
/// layer, logical row by logical row — regardless of backing.
fn assert_caches_equal(a: &SessionState, b: &SessionState) -> Result<(), String> {
    for (li, (ca, cb)) in a.caches().iter().zip(b.caches()).enumerate() {
        prop_assert(ca.len() == cb.len(), format!("layer {li}: cache length differs"))?;
        for j in 0..ca.len() {
            prop_assert(
                ca.k_row(j) == cb.k_row(j) && ca.v_row(j) == cb.v_row(j),
                format!("layer {li} logical row {j}: K/V rows differ across backings"),
            )?;
        }
    }
    Ok(())
}

#[test]
fn prop_paged_session_bit_exact_vs_ring_all_engines() {
    // ragged prompts (including longer than n_ctx) + a short greedy
    // decode chain, across all four engines. The token schedule is
    // driven from the RING session's logits so any divergence shows up
    // as a logits mismatch, not a silently different schedule.
    prop("paged prefill+decode == ring (fp/naive/muxq/llmint8)", |g| {
        let fp = model_for(g);
        let cfg = fp.cfg.clone();
        let n_ctx = cfg.n_ctx;
        let engine = g.usize(0, 3);
        let q;
        let sm = match engine {
            0 => {
                q = QuantizedGpt2::new(fp, EngineSpec::naive()); // fp lives inside
                SessionModel::Fp(&q.fp)
            }
            1 => {
                q = QuantizedGpt2::new(fp, EngineSpec::naive());
                SessionModel::Int(&q)
            }
            2 => {
                q = QuantizedGpt2::new(fp, EngineSpec::muxq());
                SessionModel::Int(&q)
            }
            _ => {
                q = QuantizedGpt2::new(fp, EngineSpec::llmint8());
                SessionModel::Int(&q)
            }
        };
        let plen = g.usize(1, n_ctx + 3); // ragged, may exceed the window
        let steps = g.usize(1, 6);
        let prompt = prompt_for(g, plen);
        let pool = pool_for(g, cfg.d_model);

        let mut ring = SessionState::new(&cfg, WrapPolicy::default());
        let mut paged = SessionState::new_paged(&cfg, WrapPolicy::default(), &pool);
        prop_assert(paged.is_paged() && !ring.is_paged(), "backing flags")?;
        let lr = err_str(ring.prefill(sm, &prompt))?;
        let lp = err_str(paged.prefill(sm, &prompt))?;
        prop_assert(lr == lp, format!("engine {engine}: prefill logits differ"))?;
        let mut next = argmax(&lr);
        for s in 0..steps {
            let lr = err_str(ring.decode_step(sm, next))?;
            let lp = err_str(paged.decode_step(sm, next))?;
            prop_assert(lr == lp, format!("engine {engine} step {s}: decode logits differ"))?;
            next = argmax(&lr);
        }
        prop_assert(ring.window() == paged.window(), "windows diverged")?;
        assert_caches_equal(&ring, &paged)?;
        drop(paged);
        prop_assert(pool.pages_in_use() == 0, "session drop leaked pages")
    });
}

#[test]
fn prop_paged_reprefill_wrap_matches_ring_past_n_ctx() {
    // generate well past n_ctx: the Reprefill wrap clears the paged
    // caches (releasing every page) and re-prefills the kept tail into
    // fresh pages — every step's logits must still equal the ring's,
    // and the wrap accounting must agree.
    prop("paged Reprefill wrap == ring", |g| {
        let fp = model_for(g);
        let cfg = fp.cfg.clone();
        let n_ctx = cfg.n_ctx;
        let holder = QuantizedGpt2::new(fp, EngineSpec::muxq());
        let sm =
            if g.bool() { SessionModel::Int(&holder) } else { SessionModel::Fp(&holder.fp) };
        let wrap = WrapPolicy::Reprefill { keep: g.usize(0, n_ctx - 1) };
        let plen = g.usize(1, n_ctx);
        let steps = n_ctx + g.usize(1, 6); // guaranteed to wrap
        let prompt = prompt_for(g, plen);
        let pool = pool_for(g, cfg.d_model);

        let mut ring = SessionState::new(&cfg, wrap);
        let mut paged = SessionState::new_paged(&cfg, wrap, &pool);
        let lr = err_str(ring.prefill(sm, &prompt))?;
        let lp = err_str(paged.prefill(sm, &prompt))?;
        prop_assert(lr == lp, "prefill logits differ")?;
        let mut next = argmax(&lr);
        for s in 0..steps {
            let lr = err_str(ring.decode_step(sm, next))?;
            let lp = err_str(paged.decode_step(sm, next))?;
            prop_assert(lr == lp, format!("step {s}: decode logits differ across a wrap"))?;
            next = argmax(&lr);
        }
        prop_assert(paged.prefills() > 1, "must have re-prefilled past n_ctx")?;
        prop_assert(paged.prefills() == ring.prefills(), "wrap counts diverged")?;
        assert_caches_equal(&ring, &paged)?;
        drop(paged);
        prop_assert(pool.pages_in_use() == 0, "wrapping session leaked pages")
    });
}

#[test]
fn prop_paged_slide_overwrite_matches_ring() {
    // Slide never clears: old slots are overwritten in place, which on
    // paged storage exercises the universal write-slot path (and COW
    // when a page is shared — here pages are private, so the overwrite
    // must happen in place without growing the pool).
    prop("paged Slide overwrite == ring", |g| {
        let fp = model_for(g);
        let cfg = fp.cfg.clone();
        let n_ctx = cfg.n_ctx;
        let holder = QuantizedGpt2::new(fp, EngineSpec::muxq());
        let sm =
            if g.bool() { SessionModel::Int(&holder) } else { SessionModel::Fp(&holder.fp) };
        let plen = g.usize(1, n_ctx);
        let steps = n_ctx + g.usize(1, 6);
        let prompt = prompt_for(g, plen);
        let pool = pool_for(g, cfg.d_model);

        let mut ring = SessionState::new(&cfg, WrapPolicy::Slide);
        let mut paged = SessionState::new_paged(&cfg, WrapPolicy::Slide, &pool);
        let lr = err_str(ring.prefill(sm, &prompt))?;
        let lp = err_str(paged.prefill(sm, &prompt))?;
        prop_assert(lr == lp, "prefill logits differ")?;
        let mut next = argmax(&lr);
        let full = pool.pages_in_use(); // a full window's footprint, at most
        for s in 0..steps {
            let lr = err_str(ring.decode_step(sm, next))?;
            let lp = err_str(paged.decode_step(sm, next))?;
            prop_assert(lr == lp, format!("step {s}: Slide decode logits differ"))?;
            next = argmax(&lr);
        }
        // once the window is full, sliding overwrites in place — the
        // footprint may only have grown while the window was filling
        let per_layer = n_ctx.div_ceil(pool.page_rows());
        prop_assert(
            pool.pages_in_use() <= per_layer * cfg.n_layer && pool.pages_in_use() >= full,
            "Slide footprint exceeded one full window per layer",
        )?;
        assert_caches_equal(&ring, &paged)?;
        drop(paged);
        prop_assert(pool.pages_in_use() == 0, "sliding session leaked pages")
    });
}

#[test]
fn prop_paged_truncate_to_matches_ring() {
    // the rollback primitive in isolation: extend_scored a batch of
    // tokens, truncate part of it back (releasing now-dead pages), then
    // decode — every observable must match the ring twin.
    prop("paged extend+truncate_to == ring", |g| {
        let fp = model_for(g);
        let cfg = fp.cfg.clone();
        let n_ctx = cfg.n_ctx;
        let holder = QuantizedGpt2::new(fp, EngineSpec::muxq());
        let sm =
            if g.bool() { SessionModel::Int(&holder) } else { SessionModel::Fp(&holder.fp) };
        let plen = g.usize(1, n_ctx - 3);
        let ext = g.usize(1, n_ctx - plen - 1);
        let keep = g.usize(0, ext); // tokens of the extension that survive
        let prompt = prompt_for(g, plen);
        let tokens = prompt_for(g, ext);
        let pool = pool_for(g, cfg.d_model);

        let mut ring = SessionState::new(&cfg, WrapPolicy::default());
        let mut paged = SessionState::new_paged(&cfg, WrapPolicy::default(), &pool);
        err_str(ring.prefill(sm, &prompt))?;
        err_str(paged.prefill(sm, &prompt))?;
        let sr = err_str(ring.extend_scored(sm, &tokens))?;
        let sp = err_str(paged.extend_scored(sm, &tokens))?;
        prop_assert(sr.data == sp.data, "extend_scored logits differ")?;
        let held = pool.pages_in_use();
        ring.truncate_to(plen + keep);
        paged.truncate_to(plen + keep);
        prop_assert(pool.pages_in_use() <= held, "truncate must never allocate")?;
        prop_assert(ring.window() == paged.window(), "windows diverged after rollback")?;
        assert_caches_equal(&ring, &paged)?;
        let lr = err_str(ring.decode_step(sm, 7))?;
        let lp = err_str(paged.decode_step(sm, 7))?;
        prop_assert(lr == lp, "decode after rollback differs")?;
        drop(paged);
        prop_assert(pool.pages_in_use() == 0, "rolled-back session leaked pages")
    });
}

#[test]
fn prop_spec_rollback_on_pages_matches_ring() {
    // draft-and-verify drives extend_scored + truncate_to every round;
    // rejected drafts must leave NO trace in the paged tables, exactly
    // as they leave none in the ring. Both greedy and seeded stochastic
    // streams must be identical token for token, and the final target
    // AND draft K/V must be bit-equal across backings.
    prop("speculative rollback paged == ring", |g| {
        let fp = model_for(g);
        let n_layer = fp.cfg.n_layer;
        let n_ctx = fp.cfg.n_ctx;
        let cfg = fp.cfg.clone();
        let holder = QuantizedGpt2::new(fp, EngineSpec::muxq());
        let sm =
            if g.bool() { SessionModel::Int(&holder) } else { SessionModel::Fp(&holder.fp) };
        let k = g.usize(1, (n_ctx - 4).min(3));
        let plen = g.usize(1, n_ctx - k - 1);
        let rounds = g.usize(1, (n_ctx - plen) / (k + 1)); // wrap-free
        let prompt = prompt_for(g, plen);
        let kind = if g.bool() {
            DraftKind::NaiveInt8
        } else {
            DraftKind::TruncateLayers(g.usize(1, n_layer))
        };
        let greedy = g.bool();
        let temperature = g.f32(0.6, 1.4);
        let seed = g.u64(1, 1 << 40);
        let draft = err_str(DraftModel::build(sm.gpt(), kind))?;
        let pool = pool_for(g, cfg.d_model);

        let run = |paged: bool| -> Result<(Vec<u32>, SpeculativeState), String> {
            let mut smp =
                if greedy { Sampler::greedy() } else { Sampler::new(temperature, 8, seed) };
            let mut dsm = smp.fork(muxq::gpt2::speculative::DRAFT_SEED_SALT);
            let mut st = err_str(if paged {
                SpeculativeState::new_paged(&cfg, draft.cfg(), k, WrapPolicy::default(), &pool)
            } else {
                SpeculativeState::new(&cfg, draft.cfg(), k, WrapPolicy::default())
            })?;
            let logits = err_str(st.prefill(sm, draft.session_model(), &prompt))?;
            let mut next = smp.sample_in_context(&logits, st.target_state().window());
            let mut ctx = prompt.clone();
            ctx.push(next);
            for _ in 0..rounds {
                let toks = err_str(st.round(sm, draft.session_model(), next, &mut smp, &mut dsm))?;
                next = *toks.last().expect("round emits >= 1 token");
                ctx.extend_from_slice(&toks);
            }
            Ok((ctx, st))
        };
        let (ctx_r, st_r) = run(false)?;
        let (ctx_p, st_p) = run(true)?;
        prop_assert(
            ctx_r == ctx_p,
            format!("{kind:?} k={k} greedy={greedy}: emitted streams differ across backings"),
        )?;
        prop_assert(
            (st_r.accepted(), st_r.drafted(), st_r.rounds())
                == (st_p.accepted(), st_p.drafted(), st_p.rounds()),
            "accept/reject accounting diverged",
        )?;
        assert_caches_equal(st_r.target_state(), st_p.target_state())?;
        assert_caches_equal(st_r.draft_state(), st_p.draft_state())?;
        drop(st_p);
        prop_assert(pool.pages_in_use() == 0, "speculative session leaked pages")
    });
}

#[test]
fn shared_prefix_pages_are_accounted_and_isolated() {
    // three sessions with a common page-aligned system prompt: the pool
    // must hold far fewer pages than three solo footprints, each later
    // session must report shared pages, and — the isolation claim —
    // divergent decodes must equal unshared ring twins bit for bit.
    let m = Gpt2Model::test_model(2, 16, 2, 12, 32, 7);
    let cfg = m.cfg.clone();
    let sm = SessionModel::Fp(&m);
    let pool = KvPool::new(64, 2, cfg.d_model);
    let mut pc = PrefixCache::new(pool.clone(), 8);
    let system: Vec<u32> = vec![3, 1, 4, 1, 5, 9]; // 6 rows = 3 pages/layer
    let tails: [u32; 3] = [11, 22, 30];

    let mut sessions = Vec::new();
    let mut prefill_logits = Vec::new();
    let mut solo_footprint = 0;
    for (i, &t) in tails.iter().enumerate() {
        let mut prompt = system.clone();
        prompt.push(t);
        let mut s = SessionState::new_paged(&cfg, WrapPolicy::default(), &pool);
        prefill_logits.push(s.prefill_cached(sm, &prompt, &mut pc).unwrap());
        if i == 0 {
            solo_footprint = pool.pages_in_use();
            assert_eq!(s.shared_pages(), 6, "registered prefix pages are shared with the cache");
        } else {
            assert!(s.shared_pages() >= 6, "session {i} shares the system-prompt pages");
        }
        sessions.push(s);
    }
    assert_eq!(pc.hits(), 2, "sessions 2 and 3 hit the registered prefix");
    assert_eq!(pc.misses(), 1);
    assert!(
        pool.pages_in_use() < 3 * solo_footprint,
        "sharing saved nothing: {} pages vs 3x{solo_footprint}",
        pool.pages_in_use()
    );

    // isolation: each session decodes a DIFFERENT token; logits must
    // equal an unshared ring twin that never touched the pool
    for ((s, &t), l) in sessions.iter_mut().zip(&tails).zip(&prefill_logits) {
        let mut prompt = system.clone();
        prompt.push(t);
        let mut twin = SessionState::new(&cfg, WrapPolicy::default());
        let tw_pre = twin.prefill(sm, &prompt).unwrap();
        assert_eq!(l, &tw_pre, "shared-prefix prefill logits differ from the unshared twin");
        let got = s.decode_step(sm, t ^ 1).unwrap();
        let want = twin.decode_step(sm, t ^ 1).unwrap();
        assert_eq!(got, want, "shared-prefix session contaminated by a sibling");
    }

    // cleanup discipline: dropping the sessions leaves only the cache's
    // registered pages; clearing the cache empties the pool
    drop(sessions);
    assert_eq!(pool.pages_in_use(), 6, "only the cached prefix survives the sessions");
    pc.clear();
    assert_eq!(pool.pages_in_use(), 0, "prefix cache leaked pages");
}

#[test]
fn paged_server_mixed_plain_and_spec_streams_match_solo() {
    // mixed batch on pooled storage: two plain sessions sharing a
    // prompt, one distinct plain, one speculative — all coalesced on one
    // server drawing from one pool. Every stream must equal its solo
    // ring-session oracle, and the server must surface prefix sharing.
    fn toks(n: usize, seed: u64) -> Vec<u32> {
        // deterministic in-vocab prompt without reaching into crate internals
        (0..n).map(|i| ((seed * 31 + i as u64 * 7) % 32) as u32).collect()
    }
    let q = QuantizedGpt2::new(Gpt2Model::test_model(2, 16, 2, 12, 32, 7), EngineSpec::muxq());
    let shared = toks(5, 3);
    let other = toks(3, 4);
    let spec_p = toks(3, 5);
    let mut want = Vec::new();
    for p in [&shared, &shared, &other, &spec_p] {
        let mut s = q.session(WrapPolicy::default());
        want.push(s.generate_greedy(p, 6).unwrap());
    }

    let backend =
        GenBackend::Int(QuantizedGpt2::new(Gpt2Model::test_model(2, 16, 2, 12, 32, 7), EngineSpec::muxq()));
    let srv = GenerationServer::start(
        backend,
        GenerationConfig { pool_pages: 96, page_rows: 2, ..Default::default() },
    );
    let reqs = [
        GenerateRequest::greedy(shared.clone(), 6),
        GenerateRequest::greedy(shared.clone(), 6),
        GenerateRequest::greedy(other.clone(), 6),
        GenerateRequest::greedy(spec_p.clone(), 6).with_speculative(2, DraftKind::NaiveInt8),
    ];
    let handles: Vec<_> = reqs.iter().map(|r| srv.submit(r.clone()).unwrap()).collect();
    for (h, w) in handles.into_iter().zip(&want) {
        assert_eq!(&h.collect_tokens().unwrap(), w);
    }
    let st = srv.stats();
    assert_eq!(st.completed, 4);
    assert_eq!(st.evicted, 0, "a 96-page pool never pressures four tiny sessions");
    assert_eq!(st.pool_refusals, 0);
    assert_eq!(st.pool_pages, 96);
    assert_eq!(st.pool_pages_in_use + st.pool_pages_free, 96);
    assert!(st.shared_pages > 0, "identical prompts must have shared prefix pages");
    assert!(st.prefix_hits >= 1, "the second identical prompt hits the prefix cache");
    assert!(st.spec_rounds > 0, "the speculative session ran rounds");
    assert!(st.shared_page_ratio() > 0.0 && st.shared_page_ratio() <= 1.0);
    srv.shutdown();
}
