//! Coordinator end-to-end: submit concurrent mixed requests through the
//! dynamic batcher and verify correctness (every request answered, ppl
//! consistent with direct execution) and the batching behaviour.

use muxq::coordinator::{Coordinator, CoordinatorConfig, ScoreRequest, VariantKey};
use muxq::data::eval_set::EvalSet;
use std::sync::Arc;
use std::time::Duration;

fn setup() -> Option<(Arc<Coordinator>, Vec<Vec<i32>>)> {
    let root = muxq::artifacts_dir();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let mut cfg = CoordinatorConfig::default();
    cfg.batcher.max_wait = Duration::from_millis(20);
    let coord = Coordinator::start(&root, cfg).unwrap();
    let eval = EvalSet::load(&root, "valid").unwrap();
    let windows = eval.windows(128, 16);
    Some((Arc::new(coord), windows))
}

#[test]
fn concurrent_mixed_requests_all_answered() {
    let Some((coord, windows)) = setup() else { return };
    let variants = ["fp16-pt", "muxq-pt", "naive-pt"];
    let mut threads = Vec::new();
    for (i, w) in windows.iter().take(12).cloned().enumerate() {
        let coord = coord.clone();
        let tag = variants[i % variants.len()];
        threads.push(std::thread::spawn(move || {
            coord
                .score(ScoreRequest {
                    variant: VariantKey::eval("sim-small", tag),
                    tokens: w,
                    ia_bits: 8.0,
                    w_bits: 8.0,
                })
                .unwrap()
        }));
    }
    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    assert_eq!(results.len(), 12);
    for r in &results {
        assert!(r.count == 127.0, "count {}", r.count);
        assert!(r.nll.is_finite() && r.nll > 0.0);
        assert!(r.ppl() > 1.0 && r.ppl() < 1e4);
    }
    let stats = coord.stats();
    assert_eq!(stats.completed, 12, "every request answered exactly once");
    assert!(stats.batches >= 3, "at least one batch per variant");
}

#[test]
fn batched_result_equals_direct_execution() {
    let Some((coord, windows)) = setup() else { return };
    // score one window through the coordinator...
    let resp = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[0].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap();
    // ...and the same window directly through a private registry
    let registry = muxq::coordinator::VariantRegistry::open_default().unwrap();
    let key = VariantKey::eval("sim-small", "muxq-pt");
    let compiled = registry.get(&key).unwrap();
    let mut toks = Vec::new();
    for _ in 0..compiled.meta.batch {
        toks.extend_from_slice(&windows[0]);
    }
    let out = compiled.run(&toks, 8.0, 8.0).unwrap();
    let direct_nll = out[0].data[0];
    let rel = (resp.nll - direct_nll).abs() / direct_nll.abs().max(1.0);
    assert!(rel < 1e-5, "batched {} vs direct {direct_nll}", resp.nll);
}

#[test]
fn admission_rejects_bad_requests() {
    let Some((coord, windows)) = setup() else { return };
    // unknown variant
    assert!(coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "nonsense-tag"),
            tokens: windows[0].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .is_err());
    // wrong sequence length
    assert!(coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "fp16-pt"),
            tokens: vec![0; 64],
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .is_err());
    // insane bit-widths
    assert!(coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[0].clone(),
            ia_bits: 99.0,
            w_bits: 8.0,
        })
        .is_err());
}

#[test]
fn bit_width_isolation_in_batches() {
    // requests at different ia_bits must produce the same results they
    // would alone (no cross-contamination through shared batches)
    let Some((coord, windows)) = setup() else { return };
    let solo8 = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[1].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap();
    let solo6 = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[1].clone(),
            ia_bits: 6.0,
            w_bits: 8.0,
        })
        .unwrap();
    assert_ne!(solo8.nll, solo6.nll, "different bits must differ");

    // now submit both concurrently; results must match the solo runs
    let c1 = coord.clone();
    let w1 = windows[1].clone();
    let t8 = std::thread::spawn(move || {
        c1.score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: w1,
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap()
    });
    let mixed6 = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[1].clone(),
            ia_bits: 6.0,
            w_bits: 8.0,
        })
        .unwrap();
    let mixed8 = t8.join().unwrap();
    assert_eq!(mixed8.nll, solo8.nll);
    assert_eq!(mixed6.nll, solo6.nll);
}

#[test]
fn graceful_shutdown_completes_inflight() {
    let Some((coord, windows)) = setup() else { return };
    let coord = Arc::try_unwrap(coord).ok().expect("sole owner");
    let h = coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "fp16-pt"),
            tokens: windows[0].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap();
    coord.shutdown();
    // the in-flight request must still be answered (drain semantics)
    let resp = h.wait().unwrap();
    assert!(resp.nll.is_finite());
}

#[test]
fn paged_pool_pressure_evicts_and_refuses_instead_of_panicking() {
    // oversubscribe a deliberately tiny KV pool: a full 12-token session
    // needs 12 pages (2 layers x 6 pages at 2 rows/page), the pool holds
    // 14, and three long-budget sessions with DISTINCT prompts (no
    // prefix sharing to discount admission) fight for it. The server
    // must never panic: demand is refused at admission or shed by
    // evicting the newest session, every stream still gets exactly one
    // terminal event, at least one session runs to its full budget, and
    // the server keeps serving afterwards.
    use muxq::coordinator::{
        FinishReason, GenBackend, GenerateRequest, GenerationConfig, GenerationServer, TokenEvent,
    };
    use muxq::gpt2::Gpt2Model;

    fn toks(n: usize, seed: u64) -> Vec<u32> {
        (0..n).map(|i| ((seed * 31 + i as u64 * 7) % 32) as u32).collect()
    }
    let srv = GenerationServer::start(
        GenBackend::Fp(Gpt2Model::test_model(2, 16, 2, 12, 32, 7)),
        GenerationConfig {
            pool_pages: 14,
            page_rows: 2,
            max_new_tokens: 64,
            ..Default::default()
        },
    );
    let handles: Vec<_> = (0..3)
        .map(|i| srv.submit(GenerateRequest::greedy(toks(6, 101 + i), 20)).unwrap())
        .collect();

    let mut full_budget = 0;
    let mut evicted = 0;
    let mut refused = 0;
    for h in handles {
        let mut tokens = 0usize;
        let mut terminal = None;
        while let Some(ev) = h.recv() {
            match ev {
                TokenEvent::Token { index, token } => {
                    assert_eq!(index, tokens, "out-of-order stream");
                    assert!(token < 32, "out-of-vocab token under pressure");
                    tokens += 1;
                }
                ev @ (TokenEvent::Done { .. } | TokenEvent::Error(_)) => {
                    assert!(terminal.is_none(), "two terminal events on one stream");
                    terminal = Some(ev);
                }
            }
        }
        match terminal.expect("stream closed without a terminal event") {
            TokenEvent::Done { reason: FinishReason::MaxTokens, generated, .. } => {
                assert_eq!(generated, 20, "full-budget session under-delivered");
                assert_eq!(tokens, 20);
                full_budget += 1;
            }
            TokenEvent::Done { reason: FinishReason::Evicted, generated, .. } => {
                // eviction ends the stream cleanly with what was produced
                assert_eq!(generated, tokens);
                assert!(tokens < 20, "an evicted session cannot also be complete");
                evicted += 1;
            }
            TokenEvent::Done { reason, .. } => panic!("unexpected finish reason {reason:?}"),
            TokenEvent::Error(e) => {
                assert!(
                    e.contains("kv pool exhausted"),
                    "pressure refusal must say why, got: {e}"
                );
                assert_eq!(tokens, 0, "refused sessions never stream tokens");
                refused += 1;
            }
        }
    }
    assert_eq!(full_budget + evicted + refused, 3, "every stream accounted for");
    assert!(full_budget >= 1, "at least one session must survive to its budget");
    assert!(evicted + refused >= 1, "a 14-page pool cannot satisfy three 12-page sessions");

    let st = srv.stats();
    assert_eq!(st.completed as usize, full_budget);
    assert_eq!(st.evicted as usize, evicted);
    assert_eq!(st.pool_refusals as usize, refused);
    // sessions returned their pages; only prefix-cache registrations may
    // still occupy the pool, and the books must balance either way
    assert_eq!(st.pool_pages_in_use + st.pool_pages_free, 14);

    // the pool recovers: a fresh request after the storm serves normally
    let after = srv.submit(GenerateRequest::greedy(toks(4, 200), 4)).unwrap();
    assert_eq!(after.collect_tokens().unwrap().len(), 4);
    assert_eq!(srv.stats().completed as usize, full_budget + 1);
    srv.shutdown();
}
