//! Coordinator end-to-end: submit concurrent mixed requests through the
//! dynamic batcher and verify correctness (every request answered, ppl
//! consistent with direct execution) and the batching behaviour.

use muxq::coordinator::{Coordinator, CoordinatorConfig, ScoreRequest, VariantKey};
use muxq::data::eval_set::EvalSet;
use std::sync::Arc;
use std::time::Duration;

fn setup() -> Option<(Arc<Coordinator>, Vec<Vec<i32>>)> {
    let root = muxq::artifacts_dir();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let mut cfg = CoordinatorConfig::default();
    cfg.batcher.max_wait = Duration::from_millis(20);
    let coord = Coordinator::start(&root, cfg).unwrap();
    let eval = EvalSet::load(&root, "valid").unwrap();
    let windows = eval.windows(128, 16);
    Some((Arc::new(coord), windows))
}

#[test]
fn concurrent_mixed_requests_all_answered() {
    let Some((coord, windows)) = setup() else { return };
    let variants = ["fp16-pt", "muxq-pt", "naive-pt"];
    let mut threads = Vec::new();
    for (i, w) in windows.iter().take(12).cloned().enumerate() {
        let coord = coord.clone();
        let tag = variants[i % variants.len()];
        threads.push(std::thread::spawn(move || {
            coord
                .score(ScoreRequest {
                    variant: VariantKey::eval("sim-small", tag),
                    tokens: w,
                    ia_bits: 8.0,
                    w_bits: 8.0,
                })
                .unwrap()
        }));
    }
    let results: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    assert_eq!(results.len(), 12);
    for r in &results {
        assert!(r.count == 127.0, "count {}", r.count);
        assert!(r.nll.is_finite() && r.nll > 0.0);
        assert!(r.ppl() > 1.0 && r.ppl() < 1e4);
    }
    let stats = coord.stats();
    assert_eq!(stats.completed, 12, "every request answered exactly once");
    assert!(stats.batches >= 3, "at least one batch per variant");
}

#[test]
fn batched_result_equals_direct_execution() {
    let Some((coord, windows)) = setup() else { return };
    // score one window through the coordinator...
    let resp = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[0].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap();
    // ...and the same window directly through a private registry
    let registry = muxq::coordinator::VariantRegistry::open_default().unwrap();
    let key = VariantKey::eval("sim-small", "muxq-pt");
    let compiled = registry.get(&key).unwrap();
    let mut toks = Vec::new();
    for _ in 0..compiled.meta.batch {
        toks.extend_from_slice(&windows[0]);
    }
    let out = compiled.run(&toks, 8.0, 8.0).unwrap();
    let direct_nll = out[0].data[0];
    let rel = (resp.nll - direct_nll).abs() / direct_nll.abs().max(1.0);
    assert!(rel < 1e-5, "batched {} vs direct {direct_nll}", resp.nll);
}

#[test]
fn admission_rejects_bad_requests() {
    let Some((coord, windows)) = setup() else { return };
    // unknown variant
    assert!(coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "nonsense-tag"),
            tokens: windows[0].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .is_err());
    // wrong sequence length
    assert!(coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "fp16-pt"),
            tokens: vec![0; 64],
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .is_err());
    // insane bit-widths
    assert!(coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[0].clone(),
            ia_bits: 99.0,
            w_bits: 8.0,
        })
        .is_err());
}

#[test]
fn bit_width_isolation_in_batches() {
    // requests at different ia_bits must produce the same results they
    // would alone (no cross-contamination through shared batches)
    let Some((coord, windows)) = setup() else { return };
    let solo8 = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[1].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap();
    let solo6 = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[1].clone(),
            ia_bits: 6.0,
            w_bits: 8.0,
        })
        .unwrap();
    assert_ne!(solo8.nll, solo6.nll, "different bits must differ");

    // now submit both concurrently; results must match the solo runs
    let c1 = coord.clone();
    let w1 = windows[1].clone();
    let t8 = std::thread::spawn(move || {
        c1.score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: w1,
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap()
    });
    let mixed6 = coord
        .score(ScoreRequest {
            variant: VariantKey::eval("sim-small", "muxq-pt"),
            tokens: windows[1].clone(),
            ia_bits: 6.0,
            w_bits: 8.0,
        })
        .unwrap();
    let mixed8 = t8.join().unwrap();
    assert_eq!(mixed8.nll, solo8.nll);
    assert_eq!(mixed6.nll, solo6.nll);
}

#[test]
fn graceful_shutdown_completes_inflight() {
    let Some((coord, windows)) = setup() else { return };
    let coord = Arc::try_unwrap(coord).ok().expect("sole owner");
    let h = coord
        .submit(ScoreRequest {
            variant: VariantKey::eval("sim-small", "fp16-pt"),
            tokens: windows[0].clone(),
            ia_bits: 8.0,
            w_bits: 8.0,
        })
        .unwrap();
    coord.shutdown();
    // the in-flight request must still be answered (drain semantics)
    let resp = h.wait().unwrap();
    assert!(resp.nll.is_finite());
}
