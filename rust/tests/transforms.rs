//! Transform-algebra integration suite: the [`PreTransform`] pipeline's
//! contracts, end to end through packed operators.
//!
//! * rotation orthogonality (R·Rᵀ = I within tol) and norm preservation;
//! * permutation round-trip BIT-exactness (a gather moves bits, it never
//!   touches them);
//! * transformed-then-quantized forwards track the fp32 oracle within
//!   the same llmint8-style tolerances the mixed-precision baseline is
//!   held to — for every pipeline composition, in every order;
//! * pipeline order is observable (`-sq-rot` ≠ `-rot-sq` numerically,
//!   which is why the tag spells it);
//! * the Table-1-style eval: rotated specs show LOWER quantization
//!   error than their un-rotated twins on outlier-bearing inputs (the
//!   DuQuant claim, reproduced on this engine);
//! * calibrated ResQ rank selection: the energy threshold finds exactly
//!   the calibration-hot channels, observable through `bytes()`.

use muxq::data::prng::SplitMix64;
use muxq::quant::gemm::matmul_f32;
use muxq::quant::transform::{invert_perm, zigzag_perm, BlockRot, ROT_BLOCK};
use muxq::quant::{EngineSpec, MatF32, QuantLinear};
use muxq::util::proptest::{prop, prop_assert, Gen};

fn rand_mat(g: &mut Gen, rows: usize, cols: usize, scale: f32) -> MatF32 {
    MatF32::from_vec(rows, cols, g.vec_f32(rows * cols, -scale, scale)).unwrap()
}

/// Per-input-channel activation abs-max — the calibration statistic
/// `pack_calibrated` consumes.
fn col_absmax(x: &MatF32) -> Vec<f32> {
    let mut a = vec![0.0f32; x.cols];
    for r in 0..x.rows {
        for (c, v) in x.row(r).iter().enumerate() {
            a[c] = a[c].max(v.abs());
        }
    }
    a
}

#[test]
fn prop_block_rotation_is_orthogonal() {
    // extract R column by column (apply_to_row computes x·Rᵀ, so the
    // image of basis vector e_i is R's i-th column over output index j)
    // and check RᵀR = I within 1e-4 — plus norm preservation on a
    // random vector, the property quantization error bounds lean on
    prop("BlockRot is orthogonal", |g| {
        let k = g.usize(2, 48);
        let block = *g.choice(&[8usize, ROT_BLOCK]);
        let rot = BlockRot::build(k, block);
        let mut cols = vec![vec![0.0f32; k]; k];
        let mut out = vec![0.0f32; k];
        for i in 0..k {
            let mut e = vec![0.0f32; k];
            e[i] = 1.0;
            rot.apply_to_row(&e, &mut out);
            for j in 0..k {
                cols[i][j] = out[j];
            }
        }
        for a in 0..k {
            for b in a..k {
                let dot: f32 = (0..k).map(|j| cols[a][j] * cols[b][j]).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                prop_assert(
                    (dot - want).abs() < 1e-4,
                    format!("k={k} block={block}: col{a}·col{b} = {dot}"),
                )?;
            }
        }
        let v = g.vec_f32(k, -5.0, 5.0);
        rot.apply_to_row(&v, &mut out);
        let n_in: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
        let n_out: f32 = out.iter().map(|x| x * x).sum::<f32>().sqrt();
        prop_assert(
            (n_in - n_out).abs() <= 1e-3 * n_in.max(1.0),
            format!("norm {n_in} -> {n_out}"),
        )
    });
}

#[test]
fn prop_permutation_round_trips_bit_exact() {
    // a zigzag gather is a relabeling: applying it and then its inverse
    // must reproduce the input BIT for bit (f32 equality, no epsilon)
    prop("zigzag perm round-trips bit-exact", |g| {
        let k = g.usize(2, 64);
        let amax = g.vec_f32(k, 0.0, 40.0);
        let p = zigzag_perm(&amax, ROT_BLOCK);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        prop_assert(
            sorted == (0..k).collect::<Vec<_>>(),
            format!("not a permutation of 0..{k}: {p:?}"),
        )?;
        let inv = invert_perm(&p);
        let x = g.vec_f32(k, -10.0, 10.0);
        let gathered: Vec<f32> = p.iter().map(|&src| x[src]).collect();
        let back: Vec<f32> = inv.iter().map(|&src| gathered[src]).collect();
        prop_assert(back == x, "gather ∘ inverse-gather must be the identity")
    });
}

#[test]
fn prop_transformed_quantized_forward_tracks_fp32_oracle() {
    // every pipeline composition, every order, three INT methods: the
    // transformed-then-quantized forward must stay within the same
    // tolerance band the llmint8 deployment test pins (mae < 0.25 at
    // these operand scales) — transforms redistribute magnitude, they
    // must never amplify quantization error on tame inputs
    prop("transformed INT forward ~ fp32 oracle", |g| {
        let (m, k, n) = (g.usize(2, 12), g.usize(8, 32), g.usize(2, 16));
        let x = rand_mat(g, m, k, 4.0);
        let w = rand_mat(g, k, n, 2.0);
        let base = [EngineSpec::naive(), EngineSpec::muxq(), EngineSpec::llmint8()];
        let mut spec = g.choice(&base).clone();
        for _ in 0..g.usize(1, 3) {
            spec = match g.usize(0, 2) {
                0 => spec.with_smooth(0.5),
                1 => spec.with_rotate(),
                _ => spec.with_permute(),
            };
        }
        let amax = col_absmax(&x);
        let op = spec.pack_calibrated(&w, &vec![0.0; n], Some(&amax));
        let got = op.forward(&x);
        let oracle = matmul_f32(&x, &w);
        let mae = got.mean_abs_diff(&oracle);
        prop_assert(mae < 0.25, format!("{}: mae {mae}", spec.tag()))
    });
}

/// Deterministic outlier-bearing instance: base ±1 values with hot
/// activation CHANNELS (the paper's premise — channel-structured, hit
/// every token) and heavy weight ROWS (the W4 pain: one row inflates
/// every per-column scale), both spread one-per-rotation-block.
fn outlier_instance(rng: &mut SplitMix64, m: usize, k: usize, n: usize) -> (MatF32, MatF32) {
    let mut xv = Vec::with_capacity(m * k);
    for _ in 0..m * k {
        xv.push((rng.next_f64() as f32 - 0.5) * 2.0);
    }
    let mut x = MatF32::from_vec(m, k, xv).unwrap();
    for c in [5usize, 21, 37, 53] {
        for r in 0..m {
            *x.at_mut(r, c % k) *= 30.0;
        }
    }
    let mut wv = Vec::with_capacity(k * n);
    for _ in 0..k * n {
        wv.push((rng.next_f64() as f32 - 0.5) * 2.0);
    }
    let mut w = MatF32::from_vec(k, n, wv).unwrap();
    for hr in [10usize, 30, 50] {
        let hr = hr % k;
        for j in 0..n {
            *w.at_mut(hr, j) *= 30.0;
        }
    }
    (x, w)
}

/// Total MAE of `spec` against the fp32 oracle over 8 outlier-bearing
/// instances — the operator-level Table-1-style eval.
fn eval_mae(spec: &EngineSpec, seed: u64) -> f32 {
    let (m, k, n) = (16usize, 64usize, 48usize);
    let mut rng = SplitMix64::new(seed);
    let mut total = 0.0f32;
    for _ in 0..8 {
        let (x, w) = outlier_instance(&mut rng, m, k, n);
        let amax = col_absmax(&x);
        let op = spec.pack_calibrated(&w, &vec![0.0; n], Some(&amax));
        total += op.forward(&x).mean_abs_diff(&matmul_f32(&x, &w));
    }
    total
}

#[test]
fn table1_style_rotated_specs_beat_unrotated_twins() {
    // the acceptance claim: on outlier-bearing inputs the rotated spec
    // shows LOWER quantization error than its un-rotated twin — for the
    // W4A8 nibble engine (muxq AND naive, permuted variant included)
    // and for the W8 muxq engine where the effect is largest
    let seed = 0x7AB1E1;
    let pairs: [(EngineSpec, EngineSpec); 4] = [
        (
            EngineSpec::muxq().with_bits(8, 4),
            EngineSpec::muxq().with_bits(8, 4).with_rotate(),
        ),
        (
            EngineSpec::naive().with_bits(8, 4),
            EngineSpec::naive().with_bits(8, 4).with_rotate().with_permute(),
        ),
        (
            EngineSpec::naive().with_bits(8, 4),
            EngineSpec::naive().with_bits(8, 4).with_permute().with_rotate(),
        ),
        (EngineSpec::muxq(), EngineSpec::muxq().with_rotate()),
    ];
    for (plain, transformed) in pairs {
        let e_plain = eval_mae(&plain, seed);
        let e_rot = eval_mae(&transformed, seed);
        assert!(
            e_rot < e_plain,
            "{} (mae {e_rot}) must beat {} (mae {e_plain})",
            transformed.tag(),
            plain.tag()
        );
    }
}

#[test]
fn pipeline_order_is_observable() {
    // -sq-rot calibrates the smooth in the unrotated basis, -rot-sq in
    // the rotated one: different operators, different outputs. The tag
    // grammar spells pipeline order precisely because of this.
    let mut rng = SplitMix64::new(0x0BDE8);
    let (x, w) = outlier_instance(&mut rng, 8, 64, 32);
    let amax = col_absmax(&x);
    let run = |spec: EngineSpec| {
        spec.pack_calibrated(&w, &vec![0.0; 32], Some(&amax)).forward(&x)
    };
    let sq_rot = run(EngineSpec::muxq().with_smooth(0.5).with_rotate());
    let rot_sq = run(EngineSpec::muxq().with_rotate().with_smooth(0.5));
    assert!(
        sq_rot.mean_abs_diff(&rot_sq) > 1e-4,
        "sq-rot and rot-sq must be numerically distinct operators"
    );
    let rot_perm = run(EngineSpec::naive().with_rotate().with_permute());
    let perm_rot = run(EngineSpec::naive().with_permute().with_rotate());
    assert!(
        rot_perm.mean_abs_diff(&perm_rot) > 1e-4,
        "rot-perm and perm-rot must be numerically distinct operators"
    );
    // and both orders still track the oracle (sanity on the eval above)
    let oracle = matmul_f32(&x, &w);
    for (tag, y) in [("sq-rot", &sq_rot), ("rot-sq", &rot_sq)] {
        let mae = y.mean_abs_diff(&oracle);
        assert!(mae < 2.0, "{tag}: mae {mae} exploded");
    }
}

#[test]
fn calibrated_resq_rank_tracks_energy() {
    // the energy threshold finds exactly the calibration-hot channels;
    // rank is observable through bytes() (each residual row costs
    // 2n + 4 bytes: fp16 stand-in row + one index)
    let mut rng = SplitMix64::new(0xCA11B);
    let (k, n) = (64usize, 32usize);
    let mut wv = Vec::with_capacity(k * n);
    for _ in 0..k * n {
        wv.push((rng.next_f64() as f32 - 0.5) * 2.0);
    }
    let w = MatF32::from_vec(k, n, wv).unwrap();
    let bias = vec![0.0f32; n];

    // five channels dominate the weighted residual energy by ~2500x
    let mut amax = vec![1.0f32; k];
    for c in [3usize, 17, 29, 41, 59] {
        amax[c] = 50.0;
    }
    let calibrated = EngineSpec::resq().pack_calibrated(&w, &bias, Some(&amax));
    let pinned5 = EngineSpec::resq().with_resid_rank(5).pack_calibrated(&w, &bias, Some(&amax));
    assert_eq!(
        calibrated.bytes(),
        pinned5.bytes(),
        "energy threshold must pick exactly the 5 hot channels"
    );

    // explicit rank override is exact: one more row costs 2n + 4 bytes
    let r4 = EngineSpec::resq().with_resid_rank(4).pack_calibrated(&w, &bias, Some(&amax));
    assert_eq!(pinned5.bytes() - r4.bytes(), 2 * n + 4);

    // uncalibrated pack keeps the k/16 fallback (= 4 here) — the
    // pre-redesign behavior, bit for bit in bytes
    let uncal = EngineSpec::resq().pack(&w, &bias);
    assert_eq!(uncal.bytes(), r4.bytes(), "uncalibrated fallback is k/16");

    // flat calibration has no energy outliers: rank clamps to 1
    let flat = EngineSpec::resq().pack_calibrated(&w, &bias, Some(&vec![1.0f32; k]));
    let r1 = EngineSpec::resq().with_resid_rank(1).pack_calibrated(&w, &bias, Some(&amax));
    assert_eq!(flat.bytes(), r1.bytes(), "flat calibration clamps to rank 1");

    // more hot channels -> more residual rows kept
    let mut amax10 = vec![1.0f32; k];
    for c in 0..10 {
        amax10[c * 6 + 1] = 50.0;
    }
    let cal10 = EngineSpec::resq().pack_calibrated(&w, &bias, Some(&amax10));
    assert!(cal10.bytes() > calibrated.bytes(), "hotter calibration keeps more rows");
}
