//! HTTP serving front end over real loopback sockets: the full
//! submit → SSE stream → finish round trip bit-exact against a solo
//! [`DecodeSession`], malformed bodies answered 400, and admission
//! shedding (per-tenant 429, whole-queue 503, both with `Retry-After`).
//! The socket-free wire-format pieces are unit-tested in
//! `serve::api`; this file is the black-box twin that drives the real
//! listener, worker pool, and chunked-transfer writer.
//!
//! [`DecodeSession`]: muxq::gpt2::DecodeSession

use muxq::coordinator::batcher::QosConfig;
use muxq::coordinator::{GenBackend, GenerationConfig, GenerationServer};
use muxq::gpt2::{Gpt2Model, QuantizedGpt2, WrapPolicy};
use muxq::quant::EngineSpec;
use muxq::serve::{HttpServer, ServeConfig};
use muxq::util::json::Json;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn completions_raw(body: &str) -> String {
    format!(
        "POST /v1/completions HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
}

/// One-shot exchange: send, read until the server closes (every route
/// answers `Connection: close`).
fn roundtrip(addr: SocketAddr, raw: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// Parse one streamed completion: returns (tokens, finish, generated).
/// Asserts SSE invariants along the way: contiguous indices, exactly
/// one finish event, `[DONE]` last.
fn stream_completion(addr: SocketAddr, body: &str) -> (Vec<u32>, String, usize) {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(completions_raw(body).as_bytes()).unwrap();
    let mut r = BufReader::new(s);
    let mut status = String::new();
    r.read_line(&mut status).unwrap();
    assert!(status.starts_with("HTTP/1.1 200 OK"), "{status}");
    let mut head = String::new();
    loop {
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        if line.trim_end().is_empty() {
            break;
        }
        head.push_str(&line);
    }
    assert!(head.contains("Content-Type: text/event-stream"), "{head}");
    assert!(head.contains("Transfer-Encoding: chunked"), "{head}");

    let mut tokens = Vec::new();
    let mut finish = None;
    let mut done = false;
    for line in r.lines() {
        let line = line.unwrap();
        // chunk-size and blank framing lines never start with `data: `
        let Some(data) = line.trim_end().strip_prefix("data: ") else { continue };
        assert!(!done, "event after [DONE]: {data}");
        if data == "[DONE]" {
            done = true;
            continue;
        }
        let j = Json::parse(data).unwrap();
        if let Ok(t) = j.get("token") {
            assert!(finish.is_none(), "token after finish event");
            let index = j.get("index").unwrap().as_usize().unwrap();
            assert_eq!(index, tokens.len(), "indices must be contiguous");
            tokens.push(t.as_usize().unwrap() as u32);
        } else {
            let f = j.get("finish").unwrap_or_else(|_| panic!("unexpected event {data}"));
            let gen = j.get("generated").unwrap().as_usize().unwrap();
            assert!(finish.replace((f.as_str().unwrap().to_string(), gen)).is_none());
        }
    }
    assert!(done, "stream ended without data: [DONE]");
    let (reason, generated) = finish.expect("stream ended without a finish event");
    (tokens, reason, generated)
}

#[test]
fn streamed_and_buffered_completions_are_bit_exact_vs_solo_session() {
    // the quantized engine end to end: what the wire delivers must be
    // the same tokens a solo DecodeSession produces for the same prompt
    let fp = Gpt2Model::test_model(2, 32, 2, 48, 64, 7);
    let spec = EngineSpec::muxq();
    let gen = Arc::new(GenerationServer::start(
        GenBackend::Int(QuantizedGpt2::new(fp.clone(), spec.clone())),
        GenerationConfig { max_new_tokens: 16, ..Default::default() },
    ));
    let srv = HttpServer::start(
        gen.clone(),
        ServeConfig { model_id: "tiny".into(), engine_tag: spec.tag(), ..Default::default() },
    )
    .unwrap();
    let addr = srv.addr();

    let prompt: Vec<u32> = vec![3, 1, 4, 1, 5];
    let steps = 10;
    let want = QuantizedGpt2::new(fp, spec)
        .session(WrapPolicy::default())
        .generate_greedy(&prompt, steps)
        .unwrap();

    let body = format!("{{\"prompt\": [3, 1, 4, 1, 5], \"max_tokens\": {steps}}}");
    let (tokens, reason, generated) = stream_completion(addr, &body);
    assert_eq!(reason, "length");
    assert_eq!(generated, steps);
    assert_eq!(tokens, want, "streamed tokens diverged from solo session");

    // the buffered (non-streaming) path serves the identical tokens
    let buffered = format!(
        "{{\"prompt\": [3, 1, 4, 1, 5], \"max_tokens\": {steps}, \"stream\": false}}"
    );
    let resp = roundtrip(addr, &completions_raw(&buffered));
    assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "{resp}");
    let json_start = resp.find("\r\n\r\n").unwrap() + 4;
    let j = Json::parse(resp[json_start..].trim()).unwrap();
    let got: Vec<u32> = j
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_usize().unwrap() as u32)
        .collect();
    assert_eq!(got, want, "buffered tokens diverged from solo session");
    assert_eq!(gen.stats().completed, 2);
    srv.shutdown();
}

#[test]
fn malformed_bodies_answer_400_without_touching_the_scheduler() {
    let gen = Arc::new(GenerationServer::start(
        GenBackend::Fp(Gpt2Model::test_model(2, 16, 2, 12, 32, 7)),
        GenerationConfig::default(),
    ));
    let srv = HttpServer::start(gen.clone(), ServeConfig::default()).unwrap();
    for bad in [
        "this is not json",
        r#"{"max_tokens": 4}"#,                       // no prompt
        r#"{"prompt": "words", "max_tokens": 4}"#,    // prompt not an id array
        r#"{"prompt": [1, -3], "max_tokens": 4}"#,    // negative id
        r#"{"prompt": [1, 2], "max_tokens": 2.5}"#,   // fractional budget
        r#"{"prompt": [1, 2], "top_p": 1.5}"#,        // out-of-range nucleus
    ] {
        let resp = roundtrip(srv.addr(), &completions_raw(bad));
        assert!(resp.starts_with("HTTP/1.1 400 "), "{bad:?} -> {resp}");
        assert!(resp.contains("\"error\""), "{bad:?} -> {resp}");
    }
    let st = gen.stats();
    assert_eq!(st.submitted, 0, "malformed bodies must be rejected pre-submit");
    assert_eq!(gen.metrics().counter("http_400").get(), 6);
    srv.shutdown();
}

/// Open a long-budget stream and hold it until the first token arrives,
/// proving the session is live (admitted, not queued).
fn open_live_stream(addr: SocketAddr, tenant: &str) -> TcpStream {
    let body = format!("{{\"prompt\": [1, 2, 3], \"max_tokens\": 50000, \"tenant\": {tenant:?}}}");
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(completions_raw(&body).as_bytes()).unwrap();
    let mut r = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    loop {
        line.clear();
        r.read_line(&mut line).unwrap();
        if line.contains("\"token\"") {
            return s;
        }
        assert!(!line.is_empty(), "stream closed before first token");
    }
}

fn wait_queued(gen: &GenerationServer, n: usize) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while gen.stats().queued_now < n {
        assert!(Instant::now() < deadline, "queue never reached {n}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn noisy_tenant_sheds_429_while_others_still_admit() {
    let gen = Arc::new(GenerationServer::start(
        GenBackend::Fp(Gpt2Model::test_model(2, 16, 2, 12, 32, 7)),
        GenerationConfig {
            max_live: 1,
            max_new_tokens: 50_000,
            qos: QosConfig { max_queue_per_tenant: 1, ..QosConfig::default() },
            ..Default::default()
        },
    ));
    let srv = HttpServer::start(gen.clone(), ServeConfig::default()).unwrap();
    let addr = srv.addr();

    // one live session + one queued request saturate tenant "noisy"
    let live = open_live_stream(addr, "noisy");
    let mut queued = TcpStream::connect(addr).unwrap();
    let qbody = r#"{"prompt": [4, 5], "max_tokens": 4, "tenant": "noisy"}"#;
    queued.write_all(completions_raw(qbody).as_bytes()).unwrap();
    wait_queued(&gen, 1);

    // the tenant's next request is shed with 429 + Retry-After...
    let resp = roundtrip(addr, &completions_raw(qbody));
    assert!(resp.starts_with("HTTP/1.1 429 "), "{resp}");
    assert!(resp.contains("Retry-After:"), "{resp}");
    assert_eq!(gen.metrics().counter("http_429").get(), 1);

    // ...while a different tenant still enters the queue (cap is per-lane)
    let mut polite = TcpStream::connect(addr).unwrap();
    polite
        .write_all(
            completions_raw(r#"{"prompt": [6], "max_tokens": 4, "tenant": "polite"}"#).as_bytes(),
        )
        .unwrap();
    wait_queued(&gen, 2);
    assert_eq!(gen.metrics().counter("http_429").get(), 1, "polite tenant was shed");

    // dropping the live stream cancels it; the queued sessions then admit,
    // find their clients gone, and cancel too — the server stays healthy
    drop(live);
    drop(queued);
    drop(polite);
    let deadline = Instant::now() + Duration::from_secs(5);
    while gen.stats().cancelled < 3 {
        assert!(Instant::now() < deadline, "expected 3 cancelled, {:?}", gen.stats());
        std::thread::sleep(Duration::from_millis(2));
    }
    srv.shutdown();
}

#[test]
fn full_queue_sheds_503_with_retry_after() {
    let gen = Arc::new(GenerationServer::start(
        GenBackend::Fp(Gpt2Model::test_model(2, 16, 2, 12, 32, 7)),
        GenerationConfig {
            max_live: 1,
            max_queue: 1,
            max_new_tokens: 50_000,
            ..Default::default()
        },
    ));
    let srv = HttpServer::start(gen.clone(), ServeConfig::default()).unwrap();
    let addr = srv.addr();

    let live = open_live_stream(addr, "a");
    let mut queued = TcpStream::connect(addr).unwrap();
    queued
        .write_all(completions_raw(r#"{"prompt": [4], "max_tokens": 4}"#).as_bytes())
        .unwrap();
    wait_queued(&gen, 1);

    // queue full: ANY tenant is refused now
    let resp = roundtrip(addr, &completions_raw(r#"{"prompt": [5], "max_tokens": 4}"#));
    assert!(resp.starts_with("HTTP/1.1 503 "), "{resp}");
    assert!(resp.contains("Retry-After:"), "{resp}");
    assert_eq!(gen.metrics().counter("http_503").get(), 1);

    drop(live);
    drop(queued);
    srv.shutdown();
}
