//! Failure injection: corrupted/missing artifacts and malformed inputs
//! must surface as clean errors, never panics or silent corruption.

use muxq::coordinator::variants::Manifest;
use muxq::data::bpe::Bpe;
use muxq::data::tensors::{HostTensor, TensorFile};
use muxq::gpt2::{Gpt2Config, Gpt2Model};
use muxq::util::config::Config;
use muxq::util::json::Json;
use std::path::PathBuf;

fn tmpdir(name: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("muxq_failinj_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn manifest_missing_is_clean_error() {
    let d = tmpdir("nomanifest");
    let err = Manifest::load(&d).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "actionable message: {msg}");
}

#[test]
fn manifest_malformed_json_is_clean_error() {
    let d = tmpdir("badjson");
    std::fs::write(d.join("manifest.json"), "{not json").unwrap();
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn manifest_missing_fields_is_clean_error() {
    let d = tmpdir("missingfields");
    std::fs::write(d.join("manifest.json"), r#"[{"model": "m"}]"#).unwrap();
    let err = Manifest::load(&d).unwrap_err();
    assert!(format!("{err:#}").contains("missing key"));
}

fn manifest_entry(tag: &str, method: &str, gran: &str, smooth: bool, exp: u32) -> String {
    format!(
        r#"[{{"model": "sim-small", "kind": "eval", "tag": "{tag}",
             "method": "{method}", "granularity": "{gran}", "smooth": {smooth},
             "exp_factor": {exp}, "file": "f.hlo.txt", "batch": 8, "seq": 128,
             "weights": "weights/sim-small.bin"}}]"#
    )
}

#[test]
fn manifest_tag_field_drift_is_rejected() {
    // the tag is canonical (EngineSpec round-trip); redundant fields
    // that disagree with it must fail the load, not silently mislabel
    // table columns
    let d = tmpdir("tagdrift");
    let ok = manifest_entry("muxq-pt-sq", "muxq", "per-tensor", true, 2);
    std::fs::write(d.join("manifest.json"), ok).unwrap();
    let m = Manifest::load(&d).unwrap();
    assert_eq!(m.entries.len(), 1);
    let meta = m.entries.values().next().unwrap();
    assert_eq!(meta.spec().unwrap().tag(), "muxq-pt-sq");

    for (name, bad) in [
        ("method", manifest_entry("muxq-pt-sq", "naive", "per-tensor", true, 2)),
        ("granularity", manifest_entry("muxq-pt-sq", "muxq", "per-vector", true, 2)),
        ("smooth", manifest_entry("muxq-pt-sq", "muxq", "per-tensor", false, 2)),
        ("exp", manifest_entry("muxq-pt-e3", "muxq", "per-tensor", false, 2)),
        ("unparseable tag", manifest_entry("muxq-huh", "muxq", "per-tensor", false, 2)),
    ] {
        let d = tmpdir(&format!("tagdrift_{}", name.replace(' ', "_")));
        std::fs::write(d.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&d).is_err(), "{name} drift must fail the load");
    }
}

fn manifest_entry_bits(tag: &str, method: &str, ia_bits: u32, w_bits: u32) -> String {
    format!(
        r#"[{{"model": "sim-small", "kind": "eval", "tag": "{tag}",
             "method": "{method}", "granularity": "per-vector", "smooth": false,
             "exp_factor": 2, "file": "f.hlo.txt", "batch": 8, "seq": 128,
             "weights": "weights/sim-small.bin",
             "ia_bits": {ia_bits}, "w_bits": {w_bits}}}]"#
    )
}

#[test]
fn manifest_bits_resolve_from_tag_and_drift_is_rejected() {
    let load_one = |name: &str, body: String| {
        let d = tmpdir(name);
        std::fs::write(d.join("manifest.json"), body).unwrap();
        let m = Manifest::load(&d).unwrap();
        m.entries.values().next().unwrap().clone()
    };

    // no explicit fields: bits resolve from the tag suffix / method default
    let meta =
        load_one("bits_tag_only", manifest_entry("naive-pv-w4a8", "naive", "per-vector", false, 1));
    assert_eq!((meta.ia_bits, meta.w_bits), (8, 4));

    // resq's method default is W4A8 with NO suffix on the canonical tag
    let meta2 =
        load_one("bits_resq_default", manifest_entry("resq-pv", "resq", "per-vector", false, 1));
    assert_eq!((meta2.ia_bits, meta2.w_bits), (8, 4));

    // explicit fields that agree with the tag load fine
    let meta3 = load_one("bits_explicit_ok", manifest_entry_bits("muxq-pv-w4a8", "muxq", 8, 4));
    assert_eq!(meta3.w_bits, 4);

    // explicit fields that DISAGREE with the tag fail the load
    for (name, bad) in [
        ("w_bits", manifest_entry_bits("muxq-pv-w4a8", "muxq", 8, 8)),
        ("ia_bits", manifest_entry_bits("muxq-pv", "muxq", 6, 8)),
        ("resq default", manifest_entry_bits("resq-pv", "resq", 8, 8)),
    ] {
        let d = tmpdir(&format!("bits_drift_{}", name.replace(' ', "_")));
        std::fs::write(d.join("manifest.json"), bad).unwrap();
        let err = Manifest::load(&d).unwrap_err();
        assert!(
            format!("{err:#}").contains("bits drifted"),
            "{name}: bits drift must fail the load"
        );
    }
}

fn manifest_entry_pre(tag: &str, method: &str, smooth: bool, extra: &str) -> String {
    format!(
        r#"[{{"model": "sim-small", "kind": "eval", "tag": "{tag}",
             "method": "{method}", "granularity": "per-vector", "smooth": {smooth},
             "exp_factor": 2, "file": "f.hlo.txt", "batch": 8, "seq": 128,
             "weights": "weights/sim-small.bin"{extra}}}]"#
    )
}

#[test]
fn manifest_pre_transform_drift_is_rejected() {
    let load_one = |name: &str, body: String| {
        let d = tmpdir(name);
        std::fs::write(d.join("manifest.json"), body).unwrap();
        Manifest::load(&d)
    };

    // transform fields absent: the tag is the authority, flags resolve
    // from its suffixes
    let m = load_one("pre_tag_only", manifest_entry_pre("muxq-pv-rot", "muxq", false, ""))
        .unwrap();
    let meta = m.entries.values().next().unwrap();
    assert!(meta.rotate && !meta.permute);
    assert!(meta.spec().unwrap().has_rotate());

    // explicit fields that agree load fine (rank included)
    let m2 = load_one(
        "pre_explicit_ok",
        manifest_entry_pre(
            "naive-pv-rot-perm-w4a8",
            "naive",
            false,
            r#", "rotate": true, "permute": true"#,
        ),
    )
    .unwrap();
    let meta2 = m2.entries.values().next().unwrap();
    assert!(meta2.rotate && meta2.permute);
    let m3 = load_one(
        "pre_rank_ok",
        manifest_entry_pre("resq-pv-r8", "resq", false, r#", "resid_rank": 8"#),
    )
    .unwrap();
    assert_eq!(m3.entries.values().next().unwrap().resid_rank, Some(8));

    // explicit fields that DISAGREE with the tag fail the load
    for (name, bad, want_msg) in [
        (
            "rotate_false_vs_rot_tag",
            manifest_entry_pre("muxq-pv-rot", "muxq", false, r#", "rotate": false"#),
            "pre-transform drifted",
        ),
        (
            "rotate_true_vs_plain_tag",
            manifest_entry_pre("muxq-pv", "muxq", false, r#", "rotate": true"#),
            "pre-transform drifted",
        ),
        (
            "permute_false_vs_perm_tag",
            manifest_entry_pre("naive-pv-perm", "naive", false, r#", "permute": false"#),
            "pre-transform drifted",
        ),
        (
            "rank_vs_plain_resq_tag",
            manifest_entry_pre("resq-pv", "resq", false, r#", "resid_rank": 8"#),
            "resid_rank drifted",
        ),
        (
            "rank_mismatch",
            manifest_entry_pre("resq-pv-r8", "resq", false, r#", "resid_rank": 4"#),
            "resid_rank drifted",
        ),
    ] {
        let err = load_one(&format!("pre_drift_{name}"), bad).unwrap_err();
        assert!(
            format!("{err:#}").contains(want_msg),
            "{name}: wanted {want_msg:?} in error, got {err:#}"
        );
    }

    // non-canonical suffix ORDER is drift too: the tag spells pipeline
    // order, so a rank suffix before a transform suffix must not load
    assert!(
        load_one("pre_rank_order", manifest_entry_pre("resq-pv-r8-sq", "resq", true, ""))
            .is_err(),
        "rank suffix must come after the pipeline suffixes"
    );
}

#[test]
fn truncated_weights_rejected() {
    let d = tmpdir("truncweights");
    let mut tf = TensorFile::default();
    tf.tensors.insert("wte".into(), HostTensor::from_f32(vec![8, 4], &[0.5; 32]));
    let p = d.join("w.bin");
    tf.write(&p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    bytes.truncate(bytes.len() - 7);
    std::fs::write(&p, &bytes).unwrap();
    assert!(TensorFile::read(&p).is_err());
}

#[test]
fn gpt2_load_with_missing_tensors_is_clean_error() {
    let mut tf = TensorFile::default();
    tf.tensors.insert("wte".into(), HostTensor::from_f32(vec![512, 128], &vec![0.0; 512 * 128]));
    // everything else missing
    let cfg = Gpt2Config::sim("sim-small").unwrap();
    let Err(err) = Gpt2Model::load(cfg, &tf) else { panic!("expected error") };
    assert!(format!("{err:#}").contains("not found"));
}

#[test]
fn gpt2_load_with_wrong_shape_is_clean_error() {
    // build a full tiny weight set, then corrupt one shape
    let cfg = Gpt2Config::sim("sim-small").unwrap();
    let mut tf = TensorFile::default();
    let d = cfg.d_model;
    let fill = |dims: Vec<usize>| {
        let n: usize = dims.iter().product();
        HostTensor::from_f32(dims, &vec![0.01; n])
    };
    tf.tensors.insert("wte".into(), fill(vec![100, d])); // wrong vocab
    tf.tensors.insert("wpe".into(), fill(vec![cfg.n_ctx, d]));
    tf.tensors.insert("ln_f/g".into(), fill(vec![d]));
    tf.tensors.insert("ln_f/b".into(), fill(vec![d]));
    for i in 0..cfg.n_layer {
        let p = format!("block{i:02}");
        for (name, dims) in [
            ("ln_1/g", vec![d]),
            ("ln_1/b", vec![d]),
            ("ln_2/g", vec![d]),
            ("ln_2/b", vec![d]),
            ("c_attn/w", vec![d, 3 * d]),
            ("c_attn/b", vec![3 * d]),
            ("attn_proj/w", vec![d, d]),
            ("attn_proj/b", vec![d]),
            ("c_fc/w", vec![d, cfg.d_ff()]),
            ("c_fc/b", vec![cfg.d_ff()]),
            ("mlp_proj/w", vec![cfg.d_ff(), d]),
            ("mlp_proj/b", vec![d]),
        ] {
            tf.tensors.insert(format!("{p}/{name}"), fill(dims));
        }
    }
    let Err(err) = Gpt2Model::load(cfg, &tf) else { panic!("expected error") };
    assert!(format!("{err:#}").contains("inconsistent"));
}

#[test]
fn bpe_malformed_merge_table_rejected() {
    assert!(Bpe::load_str("abc def").is_err());
    assert!(Bpe::load_str("12").is_err());
    assert!(Bpe::load_str("999 0").is_err()); // future reference
}

#[test]
fn config_partial_garbage_rejected() {
    assert!(Config::parse("[ok]\nkey = v\nbroken line").is_err());
}

#[test]
fn json_deep_nesting_ok_but_garbage_rejected() {
    let deep = format!("{}1{}", "[".repeat(200), "]".repeat(200));
    assert!(Json::parse(&deep).is_ok());
    assert!(Json::parse(&"[".repeat(200)).is_err());
}

#[test]
fn tensor_u8_not_executable_input() {
    let t = HostTensor {
        dtype: muxq::data::tensors::DType::U8,
        dims: vec![4],
        data: vec![1, 2, 3, 4],
    };
    assert!(t.to_literal().is_err());
}

#[test]
fn host_tensor_dtype_mismatch_errors() {
    let t = HostTensor::from_f32(vec![2], &[1.0, 2.0]);
    assert!(t.as_i32().is_err());
    let t2 = HostTensor::from_i32(vec![2], &[1, 2]);
    assert!(t2.as_f32().is_err());
}
