//! Cross-language oracle test: the rust quantization engine must
//! reproduce the python/jax reference bit-for-bit (goldens produced by
//! `python/compile/aot.py::stage_goldens`).

use muxq::data::tensors::TensorFile;
use muxq::quant::absmax::{fake_quant, Granularity, Scales};
use muxq::quant::llmint8::fq_llmint8_act;
use muxq::quant::muxq::{decompose, fq_muxq, outlier_mask, MuxqParams};
use muxq::quant::smooth::smooth_scales;
use muxq::quant::{gemm, MatF32};

fn goldens() -> Option<TensorFile> {
    let path = muxq::artifacts_dir().join("goldens").join("quant.bin");
    if !path.exists() {
        eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
        return None;
    }
    Some(TensorFile::read(path).unwrap())
}

fn mat(tf: &TensorFile, name: &str) -> MatF32 {
    let t = tf.get(name).unwrap();
    MatF32::from_vec(t.dims[0], t.dims[1], t.as_f32().unwrap()).unwrap()
}

fn assert_close(got: &MatF32, want: &MatF32, tol: f32, what: &str) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{what} shape");
    let d = got.max_abs_diff(want);
    assert!(d <= tol, "{what}: max abs diff {d} > {tol}");
}

#[test]
fn naive_fake_quant_matches_python() {
    let Some(tf) = goldens() else { return };
    let x = mat(&tf, "x");
    let w = mat(&tf, "w");
    for (gran, gx, gw) in [
        ("pt", Granularity::PerTensor, Granularity::PerTensor),
        ("pv", Granularity::PerRow, Granularity::PerCol),
    ] {
        let sx = Scales::compute(&x, 127.0, gx);
        let got = fake_quant(&x, &sx, 127.0);
        assert_close(&got, &mat(&tf, &format!("fq_naive_x_{gran}")), 1e-6, "fq x");
        let sw = Scales::compute(&w, 127.0, gw);
        let got_w = fake_quant(&w, &sw, 127.0);
        assert_close(&got_w, &mat(&tf, &format!("fq_naive_w_{gran}")), 1e-6, "fq w");
    }
}

#[test]
fn quant_matmul_matches_python() {
    let Some(tf) = goldens() else { return };
    let x = mat(&tf, "x");
    let w = mat(&tf, "w");
    for (gran, gx, gw) in [
        ("pt", Granularity::PerTensor, Granularity::PerTensor),
        ("pv", Granularity::PerRow, Granularity::PerCol),
    ] {
        let got = gemm::quant_matmul(&x, &w, 127.0, gx, gw);
        // integer matmul is exact; dequant multiplication gives ~1e-5 rel
        let want = mat(&tf, &format!("qmm_{gran}"));
        let scale = want.absmax().max(1.0);
        assert!(
            got.max_abs_diff(&want) / scale < 1e-5,
            "qmm_{gran} rel diff {}",
            got.max_abs_diff(&want) / scale
        );
    }
}

#[test]
fn outlier_mask_and_decompose_match_python() {
    let Some(tf) = goldens() else { return };
    let x = mat(&tf, "x");
    let mask = outlier_mask(&x, 6.0);
    let want_mask = mat(&tf, "outlier_mask");
    for (c, m) in mask.iter().enumerate() {
        assert_eq!(*m, want_mask.at(0, c) > 0.5, "mask[{c}]");
    }
    let p = MuxqParams { theta: 6.0, exp_factor: 2 };
    let (body, aux) = decompose(&x, &mask, &p);
    assert_close(&body, &mat(&tf, "muxq_body"), 1e-6, "body");
    assert_close(&aux, &mat(&tf, "muxq_aux"), 1e-6, "aux");
}

#[test]
fn muxq_fake_quant_matches_python() {
    let Some(tf) = goldens() else { return };
    let x = mat(&tf, "x");
    let p = MuxqParams { theta: 6.0, exp_factor: 2 };
    for (gran, g) in [("pt", Granularity::PerTensor), ("pv", Granularity::PerRow)] {
        let got = fq_muxq(&x, 127.0, g, &p);
        assert_close(&got, &mat(&tf, &format!("fq_muxq_x_{gran}")), 1e-5, "fq_muxq");
    }
}

#[test]
fn llmint8_fake_quant_matches_python() {
    let Some(tf) = goldens() else { return };
    let x = mat(&tf, "x");
    for (gran, g) in [("pt", Granularity::PerTensor), ("pv", Granularity::PerRow)] {
        let got = fq_llmint8_act(&x, 127.0, g, 6.0);
        assert_close(&got, &mat(&tf, &format!("fq_llmint8_x_{gran}")), 1e-5, "fq_llmint8");
    }
}

#[test]
fn four_bit_matches_python() {
    let Some(tf) = goldens() else { return };
    let x = mat(&tf, "x");
    let s = Scales::compute(&x, 7.0, Granularity::PerTensor);
    let got = fake_quant(&x, &s, 7.0);
    assert_close(&got, &mat(&tf, "fq_naive_x_pt_4b"), 1e-6, "4-bit");
}

#[test]
fn smoothquant_scales_match_python() {
    let Some(tf) = goldens() else { return };
    let x = mat(&tf, "x");
    let w = mat(&tf, "w");
    let got = smooth_scales(&x.absmax_cols(), &w, 0.5);
    let want = tf.get("smooth_s").unwrap().as_f32().unwrap();
    for (i, (g, wv)) in got.iter().zip(&want).enumerate() {
        let rel = (g - wv).abs() / wv.abs().max(1e-6);
        assert!(rel < 1e-4, "smooth_s[{i}]: {g} vs {wv}");
    }
}
