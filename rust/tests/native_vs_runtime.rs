//! Native GPT-2 (pure rust f32) vs the PJRT path (jax-exported HLO):
//! the same weights + tokens must give the same NLL — validating both
//! implementations against each other.

use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::data::eval_set::EvalSet;
use muxq::gpt2::Gpt2Model;

#[test]
fn native_forward_matches_pjrt_fp16_variant() {
    let root = muxq::artifacts_dir();
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    let model = Gpt2Model::load_from_artifacts("sim-small").unwrap();
    let registry = VariantRegistry::open_default().unwrap();
    let eval = EvalSet::load(&root, "valid").unwrap();

    let key = VariantKey::eval("sim-small", "fp16-pt");
    let compiled = registry.get(&key).unwrap();
    let (batch, seq) = (compiled.meta.batch, compiled.meta.seq);
    let windows = eval.windows(seq, batch);
    let mut toks = Vec::new();
    for w in &windows {
        toks.extend_from_slice(w);
    }
    let out = compiled.run(&toks, 8.0, 8.0).unwrap();
    let pjrt_nll = out[0].data.clone();

    let windows_u32 = eval.windows_u32(seq, batch);
    let (native_nll, counts) = model.nll_per_seq(&windows_u32, None).unwrap();
    assert_eq!(counts[0], (seq - 1) as f32);

    for (i, (n, p)) in native_nll.iter().zip(&pjrt_nll).enumerate() {
        let rel = (n - p).abs() / p.abs().max(1.0);
        assert!(
            rel < 5e-3,
            "seq {i}: native {n} vs pjrt {p} (rel {rel}) — implementations diverged"
        );
    }
}

#[test]
fn native_quantized_tracks_pjrt_quantized() {
    // the rust quant engine inside the native model should show the SAME
    // ordering as the pallas path: muxq-pt < naive-pt in nll at 6 bits
    let root = muxq::artifacts_dir();
    if !root.join("manifest.json").exists() {
        return;
    }
    use muxq::quant::{Method, QuantSpec};
    let model = Gpt2Model::load_from_artifacts("sim-small").unwrap();
    let eval = EvalSet::load(&root, "valid").unwrap();
    let windows = eval.windows_u32(128, 4);

    let nll = |spec: Option<QuantSpec>| -> f32 {
        model.nll_per_seq(&windows, spec.as_ref()).unwrap().0.iter().sum()
    };
    let fp = nll(None);
    let naive6 = nll(Some(QuantSpec::new(Method::Naive, "per-tensor", 6, 8).unwrap()));
    let muxq6 = nll(Some(QuantSpec::new(Method::Muxq, "per-tensor", 6, 8).unwrap()));
    assert!(naive6 > fp, "quantization must cost something");
    assert!(muxq6 < naive6, "muxq must beat naive at 6 bits: {muxq6} vs {naive6}");
}
