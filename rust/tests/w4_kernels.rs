//! W4A8 nibble-engine invariants (via the in-repo mini-proptest): the
//! pack/unpack round-trip over the whole signed i4 range (including the
//! -8 corner the sign-extension tricks must survive), and every W4
//! contraction — dense tile grid, rows-subset Aux, skinny-M GEMV —
//! bit-exact against the i8-widened packed oracle across random ragged
//! shapes, both panel widths, and every forced kernel the host offers.
//! The CI matrix runs this on x86-64 (AVX2 nibble expand) AND arm64
//! (NEON `vshl`/`vshr`), so both SIMD unpack paths are exercised.

use muxq::quant::matrix::{MatI32, MatI8};
use muxq::quant::packed::{
    matmul_i8_packed_kernel_into, matmul_i8w4_gemv_into, matmul_i8w4_packed_into,
    matmul_i8w4_packed_kernel_into, matmul_i8w4_rows_subset_into, Kernel, PackedMatI4,
    PackedMatI8, ParallelGemm,
};
use muxq::quant::simd;
use muxq::util::proptest::{prop, prop_assert, Gen};

/// i4-range weights widened to i8 — what the 4-bit quantizer emits.
fn gen_i4(g: &mut Gen, rows: usize, cols: usize) -> MatI8 {
    let mut m = MatI8::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = g.usize(0, 15) as i8 - 8;
    }
    m
}

/// Full-range i8 activations, -128 included (the W4 pair sum is bounded
/// by 2·128·8 = 2048, so no input needs a wide fallback).
fn gen_act(g: &mut Gen, rows: usize, cols: usize) -> MatI8 {
    let mut m = MatI8::zeros(rows, cols);
    for v in m.data.iter_mut() {
        *v = (g.usize(0, 255) as i32 - 128) as i8;
    }
    m
}

/// The oracle: widen the i4 weights to i8 and run the proven i8 packed
/// engine (itself pinned against the naive triple loop elsewhere)
/// through its always-exact wide kernel.
fn widened_oracle(a: &MatI8, b: &MatI8, nr: usize, mr: usize) -> MatI32 {
    let bp = PackedMatI8::pack_with(b, nr);
    let mut c = MatI32::zeros(0, 0);
    matmul_i8_packed_kernel_into(a, &bp, &mut c, ParallelGemm::sequential(), Kernel::WideI32, mr);
    c
}

#[test]
fn prop_nibble_pack_roundtrip_full_i4_range() {
    prop("PackedMatI4 round-trips every i4 value (incl -8)", |g| {
        let k = g.usize(1, 40);
        let n = g.usize(1, 24);
        let mut b = gen_i4(g, k, n);
        if g.bool() {
            // out-of-range values must clamp, not wrap
            let at = g.usize(0, b.data.len() - 1);
            b.data[at] = *g.choice(&[-128i8, -9, 8, 127]);
        }
        let nr = *g.choice(&[4usize, 8]);
        let bp = PackedMatI4::pack_with(&b, nr);
        let want_sat = b.data.iter().any(|&v| !(-8..=7).contains(&v));
        prop_assert(bp.saturated() == want_sat, "saturation flag")?;
        let i8p = PackedMatI8::pack_with(&b, nr);
        prop_assert(
            bp.padded_bytes() * 2 == i8p.padded_bytes(),
            format!("half the panel bytes: {} vs {}", bp.padded_bytes(), i8p.padded_bytes()),
        )?;
        for kk in 0..k {
            for j in 0..n {
                let want = b.data[kk * n + j].clamp(-8, 7);
                prop_assert(
                    bp.get(kk, j) == want,
                    format!("({kk},{j}) got {} want {want} nr {nr}", bp.get(kk, j)),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn nibble_roundtrip_every_i4_value_deterministic() {
    // all 16 values down one column, in both panel widths and both
    // K-parities (odd K exercises the zero-padded high nibble)
    for nr in [4usize, 8] {
        for k in [16usize, 15] {
            let mut b = MatI8::zeros(k, 3);
            for kk in 0..k {
                for j in 0..3 {
                    b.data[kk * 3 + j] = ((kk + j) % 16) as i8 - 8;
                }
            }
            let bp = PackedMatI4::pack_with(&b, nr);
            assert!(!bp.saturated());
            for kk in 0..k {
                for j in 0..3 {
                    assert_eq!(bp.get(kk, j), b.data[kk * 3 + j], "k {kk} j {j} nr {nr}");
                }
            }
        }
    }
}

#[test]
fn prop_w4_dense_bit_exact_vs_widened_oracle() {
    // every W4 dense route — scalar pair kernel (PairI16 and WideI32
    // both name it; there is no wide fallback to fall back to) and the
    // host's SIMD kernel — across the full register-tile grid and
    // ragged shapes, against the i8-widened oracle
    prop("W4 dense GEMM == widened-i8 oracle", |g| {
        let m = g.usize(1, 40);
        let k = g.usize(1, 48);
        let n = g.usize(1, 40);
        let a = gen_act(g, m, k);
        let mut b = gen_i4(g, k, n);
        if g.bool() {
            // the -8 corner: scatter true minimums into the weights
            for _ in 0..g.usize(1, 4) {
                let at = g.usize(0, b.data.len() - 1);
                b.data[at] = -8;
            }
        }
        let nr = *g.choice(&[4usize, 8]);
        let mr = *g.choice(&[4usize, 8]);
        let want = widened_oracle(&a, &b, nr, mr);
        let bp = PackedMatI4::pack_with(&b, nr);
        let mut kernels = vec![Kernel::PairI16, Kernel::WideI32];
        if simd::host_simd().is_some() {
            kernels.push(Kernel::Simd);
        }
        for kernel in kernels {
            let mut c = MatI32::zeros(0, 0);
            matmul_i8w4_packed_kernel_into(&a, &bp, &mut c, ParallelGemm::sequential(), kernel, mr);
            prop_assert(
                c.data == want.data,
                format!("{m}x{k}x{n} {kernel:?} tile {mr}x{nr}"),
            )?;
        }
        // the routed public entry (GEMV for skinny M, tiles otherwise),
        // sequential and threaded, agrees too
        for cfg in [ParallelGemm::sequential(), ParallelGemm { threads: 3, min_parallel_macs: 0 }] {
            let mut c = MatI32::zeros(0, 0);
            matmul_i8w4_packed_into(&a, &bp, &mut c, cfg);
            prop_assert(c.data == want.data, format!("routed {m}x{k}x{n} ({} thr)", cfg.threads))?;
        }
        Ok(())
    });
}

#[test]
fn prop_w4_gemv_and_rows_subset_bit_exact() {
    // the decode path (skinny-M GEMV) and the MUXQ Aux path (compact A
    // against scattered W4 rows) vs widened oracles
    prop("W4 GEMV + rows-subset == widened-i8 oracle", |g| {
        let m = g.usize(1, 4);
        let k = g.usize(1, 48);
        let n = g.usize(1, 24);
        let a = gen_act(g, m, k);
        let b = gen_i4(g, k, n);
        let nr = *g.choice(&[4usize, 8]);
        let bp = PackedMatI4::pack_with(&b, nr);
        let want = widened_oracle(&a, &b, nr, 4);
        let mut kernels = vec![Kernel::Auto, Kernel::PairI16];
        if simd::host_simd().is_some() {
            kernels.push(Kernel::Simd);
        }
        for kernel in kernels {
            let mut c = MatI32::zeros(0, 0);
            matmul_i8w4_gemv_into(&a, &bp, &mut c, kernel);
            prop_assert(c.data == want.data, format!("gemv {m}x{k}x{n} {kernel:?} nr {nr}"))?;
        }
        // rows-subset: gather the indexed W4 rows, widen, re-run
        let r = g.usize(1, k.min(8));
        let idx: Vec<usize> = (0..r).map(|_| g.usize(0, k - 1)).collect();
        let ac = gen_act(g, m, r);
        let mut got = MatI32::zeros(0, 0);
        matmul_i8w4_rows_subset_into(&ac, &bp, &idx, &mut got, ParallelGemm::sequential());
        let mut gathered = MatI8::zeros(r, n);
        for (t, &row) in idx.iter().enumerate() {
            gathered.data[t * n..(t + 1) * n].copy_from_slice(b.row(row));
        }
        let want_aux = widened_oracle(&ac, &gathered, nr, 4);
        prop_assert(got.data == want_aux.data, format!("subset m {m} r {r} nr {nr}"))
    });
}

#[test]
fn w4_exact_on_ragged_shape_families_full_tile_grid() {
    // the deterministic twin: odd K (the padded half-byte), tiny K
    // (degenerate contractions), M/N straddling every tile boundary —
    // every (mr, nr, kernel) combination, plus the all-(-8) worst case
    // (the most negative nibble through every unpack trick) against
    // extreme activations
    let families: [&[(usize, usize, usize)]; 3] = [
        &[(4, 1, 4), (8, 3, 8), (5, 7, 9), (16, 65, 16), (6, 129, 10)], // odd K
        &[(1, 1, 1), (2, 2, 3), (9, 2, 7), (12, 4, 5)],                 // tiny K
        &[(3, 8, 5), (7, 16, 11), (9, 10, 13), (17, 12, 15)],           // M/N tails
    ];
    let mut kernels = vec![Kernel::PairI16];
    if simd::host_simd().is_some() {
        kernels.push(Kernel::Simd);
    }
    for (fi, family) in families.iter().enumerate() {
        for &(m, k, n) in family.iter() {
            let mut rng =
                muxq::data::prng::SplitMix64::new((fi * 7919 + m * 131 + k * 17 + n) as u64);
            let mut a = MatI8::zeros(m, k);
            for v in a.data.iter_mut() {
                *v = (rng.next_below(256) as i32 - 128) as i8;
            }
            let mut b = MatI8::zeros(k, n);
            for v in b.data.iter_mut() {
                *v = (rng.next_below(16) as i32 - 8) as i8;
            }
            let mut b_min = MatI8::zeros(k, n);
            b_min.data.iter_mut().for_each(|v| *v = -8);
            let mut a_min = MatI8::zeros(m, k);
            a_min.data.iter_mut().for_each(|v| *v = i8::MIN);
            for (tag, amat, bmat) in [("rand", &a, &b), ("neg8", &a_min, &b_min)] {
                for nr in [4usize, 8] {
                    let bp = PackedMatI4::pack_with(bmat, nr);
                    assert!(!bp.saturated(), "i4-range input must not clamp");
                    for mr in [4usize, 8] {
                        let want = widened_oracle(amat, bmat, nr, mr);
                        for &kernel in &kernels {
                            let mut c = MatI32::zeros(0, 0);
                            matmul_i8w4_packed_kernel_into(
                                amat,
                                &bp,
                                &mut c,
                                ParallelGemm::sequential(),
                                kernel,
                                mr,
                            );
                            assert_eq!(
                                c.data, want.data,
                                "family {fi} {tag} {m}x{k}x{n} {kernel:?} tile {mr}x{nr}"
                            );
                        }
                    }
                }
            }
        }
    }
}
