//! Cross-language determinism: the rust corpus/BPE twins must reproduce
//! the python-built artifacts exactly (the request path re-tokenizes user
//! text, so any divergence would corrupt serving results).

use muxq::data::bpe::Bpe;
use muxq::data::corpus::{CorpusConfig, CorpusGenerator};
use muxq::data::eval_set::EvalSet;
use muxq::data::tensors::TensorFile;

fn artifacts() -> Option<std::path::PathBuf> {
    let root = muxq::artifacts_dir();
    if root.join("corpus").join("tokenizer.bpe").exists() {
        Some(root)
    } else {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        None
    }
}

#[test]
fn corpus_generator_reproduces_python_train_split() {
    let Some(root) = artifacts() else { return };
    let want = std::fs::read_to_string(root.join("corpus").join("train.txt")).unwrap();
    // regenerate just the first article's worth and compare the prefix
    let gen = CorpusGenerator::new(CorpusConfig::default());
    let got = gen.split("train", Some(1));
    assert!(
        want.starts_with(&got),
        "rust corpus diverges from python:\n rust: {:?}\n py:   {:?}",
        &got[..got.len().min(80)],
        &want[..80]
    );
    assert!(got.len() > 200);
}

#[test]
fn corpus_generator_reproduces_full_valid_split() {
    let Some(root) = artifacts() else { return };
    let want = std::fs::read_to_string(root.join("corpus").join("valid.txt")).unwrap();
    let gen = CorpusGenerator::new(CorpusConfig::default());
    let got = gen.split("valid", Some(12)); // 120 articles / 10
    assert_eq!(got, want, "full valid split must match byte-for-byte");
}

#[test]
fn bpe_encode_matches_python_token_cache() {
    let Some(root) = artifacts() else { return };
    let bpe = Bpe::load(root.join("corpus").join("tokenizer.bpe")).unwrap();
    let valid_text = std::fs::read_to_string(root.join("corpus").join("valid.txt")).unwrap();
    let got: Vec<i32> = bpe.encode(&valid_text).iter().map(|&t| t as i32).collect();
    let tf = TensorFile::read(root.join("corpus").join("tokens.bin")).unwrap();
    let want = tf.get("valid").unwrap().as_i32().unwrap();
    assert_eq!(got.len(), want.len(), "token count mismatch");
    assert_eq!(got, want, "token stream mismatch");
}

#[test]
fn bpe_roundtrips_corpus() {
    let Some(root) = artifacts() else { return };
    let bpe = Bpe::load(root.join("corpus").join("tokenizer.bpe")).unwrap();
    let text = std::fs::read_to_string(root.join("corpus").join("valid.txt")).unwrap();
    let sample = &text[..text.len().min(5000)];
    assert_eq!(bpe.decode(&bpe.encode(sample)), sample);
}

#[test]
fn eval_set_windows_cover_valid_split() {
    let Some(root) = artifacts() else { return };
    let eval = EvalSet::load(&root, "valid").unwrap();
    let w = eval.windows(128, 0);
    assert!(w.len() >= 8, "valid split too small: {} windows", w.len());
    assert!(w.iter().all(|x| x.len() == 128));
    // tokens must be within the BPE vocab
    let bpe = Bpe::load(root.join("corpus").join("tokenizer.bpe")).unwrap();
    let vmax = bpe.vocab_size() as i32;
    assert!(w.iter().flatten().all(|&t| t >= 0 && t < vmax));
}
