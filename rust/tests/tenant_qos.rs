//! Multi-tenant QoS invariants for the decode admission queue and the
//! generation server built on it (via the in-repo mini-proptest):
//!
//! * under saturation, served shares track the configured DWRR weights
//!   and no backlogged tenant starves;
//! * a single-tenant queue is FIFO bit-exact (compat with the pre-QoS
//!   admission order);
//! * per-tenant queue caps and whole-queue backpressure shed exactly
//!   the requests a reference model predicts, and nothing is lost or
//!   duplicated;
//! * end to end through [`GenerationServer`], a 3:1-weighted heavy
//!   tenant finishes ~3 sessions per light-tenant session while every
//!   stream stays bit-exact vs a solo [`DecodeSession`].
//!
//! CI re-runs this file with `MUXQ_PROPTEST_CASES=200` (see
//! `rust/scripts/ci_check.sh`).
//!
//! [`DecodeSession`]: muxq::gpt2::DecodeSession

use muxq::coordinator::batcher::{AdmitError, DecodePop, DecodeQueue, QosConfig};
use muxq::coordinator::request::{GenerateRequest, PendingGen, TokenEvent};
use muxq::coordinator::{GenBackend, GenerationConfig, GenerationServer};
use muxq::gpt2::{Gpt2Model, WrapPolicy};
use muxq::util::proptest::{prop, prop_assert, Gen};
use std::sync::mpsc;
use std::time::Instant;

fn pending_for(
    tenant: &str,
    prompt: Vec<u32>,
    max_new: usize,
) -> (PendingGen, mpsc::Receiver<TokenEvent>) {
    let (tx, rx) = mpsc::channel();
    (
        PendingGen {
            req: GenerateRequest::greedy(prompt, max_new).with_tenant(tenant),
            submitted: Instant::now(),
            tx,
        },
        rx,
    )
}

// ------------------------------------------------- queue-level (DWRR)

#[test]
fn prop_dwrr_shares_track_weights_under_saturation() {
    // randomized lanes/weights/costs/quanta; push everything up front
    // (full saturation), drain, and check the served-token shares over
    // the saturated prefix. DWRR's fairness bound: a lane's service
    // count over R crediting rounds deviates from R·q·w/c by at most a
    // burst (q·w/c services) plus rounding, so shares converge to the
    // weight ratio with an O(lanes · burst) error — the tolerance below.
    prop("DWRR shares ~ weights, nobody starves", |g: &mut Gen| {
        let n_lanes = g.usize(2, 4);
        let weights: Vec<u64> = (0..n_lanes).map(|_| g.usize(1, 4) as u64).collect();
        let w_sum: u64 = weights.iter().sum();
        let w_max = *weights.iter().max().unwrap();
        let cost = g.usize(2, 8) as u64;
        let quantum = g.usize(1, 2) as u64;
        // enough backlog that the saturated prefix dwarfs the tolerance
        let per_lane = (12 * w_max) as usize;

        let mut qos = QosConfig {
            quantum_tokens: quantum,
            default_cost_tokens: cost,
            ..QosConfig::default()
        };
        for (i, &w) in weights.iter().enumerate() {
            qos.weights.push((format!("t{i}"), w as usize));
        }
        let q = DecodeQueue::with_qos(4096, qos);
        let mut rxs = Vec::new();
        for j in 0..per_lane {
            for i in 0..n_lanes {
                let (p, r) = pending_for(&format!("t{i}"), vec![j as u32], cost as usize);
                q.push(p).unwrap();
                rxs.push(r);
            }
        }

        let mut served: Vec<usize> = Vec::new(); // lane index per pop
        while let DecodePop::Req(p) = q.pop(false) {
            let lane: usize = p.req.tenant.strip_prefix('t').unwrap().parse().unwrap();
            served.push(lane);
        }
        prop_assert(
            served.len() == per_lane * n_lanes,
            format!("drained {} of {}", served.len(), per_lane * n_lanes),
        )?;

        // saturated prefix: pops made while EVERY lane was still backlogged
        let mut count = vec![0usize; n_lanes];
        let mut prefix = 0;
        for &lane in &served {
            count[lane] += 1;
            prefix += 1;
            if count[lane] == per_lane {
                break;
            }
        }
        let mut in_prefix = vec![0usize; n_lanes];
        for &lane in &served[..prefix] {
            in_prefix[lane] += 1;
        }
        for (i, &got) in in_prefix.iter().enumerate() {
            let expected = prefix as f64 * weights[i] as f64 / w_sum as f64;
            let burst = (quantum * weights[i]) as f64 / cost as f64;
            let tol = 3.0 + (n_lanes as f64) * (burst + 1.0);
            prop_assert(
                (got as f64 - expected).abs() <= tol,
                format!(
                    "lane {i} (w {}): served {got} of {prefix}, expected {expected:.1} ± {tol:.1}",
                    weights[i]
                ),
            )?;
        }
        // no starvation: every lane is served early, not just eventually
        let window = (3 * quantum * w_sum) as usize + n_lanes;
        for i in 0..n_lanes {
            let first = served.iter().position(|&l| l == i).unwrap();
            prop_assert(
                first < window,
                format!("lane {i} first served at pop {first}, window {window}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_single_tenant_queue_is_fifo_bit_exact() {
    // one lane must reproduce the pre-QoS FIFO admission order exactly,
    // whatever the costs, quantum, or (irrelevant) weight table say
    prop("single lane == FIFO", |g: &mut Gen| {
        let tenant = if g.bool() { "solo" } else { "" };
        let qos = QosConfig {
            quantum_tokens: g.usize(1, 64) as u64,
            default_cost_tokens: g.usize(1, 256) as u64,
            weights: vec![("solo".to_string(), g.usize(1, 9))],
            ..QosConfig::default()
        };
        let q = DecodeQueue::with_qos(4096, qos);
        let n = g.usize(1, 40);
        let mut rxs = Vec::new();
        for j in 0..n {
            let (p, r) = pending_for(tenant, vec![j as u32], g.usize(1, 300));
            q.push(p).unwrap();
            rxs.push(r);
        }
        for j in 0..n {
            match q.pop(false) {
                DecodePop::Req(p) => {
                    prop_assert(
                        p.req.prompt == vec![j as u32],
                        format!("pop {j} got prompt {:?}", p.req.prompt),
                    )?;
                }
                _ => return Err(format!("pop {j}: queue empty early")),
            }
        }
        prop_assert(matches!(q.pop(false), DecodePop::Empty), "queue not drained")
    });
}

#[test]
fn prop_caps_shed_exactly_what_the_reference_model_predicts() {
    // differential state machine: random push/pop interleavings vs a
    // trivial per-lane counter model. Admission verdicts (Ok /
    // TenantBusy / QueueFull) and conservation must match exactly.
    prop("cap shedding == reference model", |g: &mut Gen| {
        let n_lanes = g.usize(1, 4);
        let cap = g.usize(0, 3);
        let max_queue = g.usize(1, 24);
        let qos = QosConfig { max_queue_per_tenant: cap, ..QosConfig::default() };
        let q = DecodeQueue::with_qos(max_queue, qos);

        let mut model = vec![0usize; n_lanes]; // queued per lane
        let mut accepted = vec![0usize; n_lanes];
        let mut popped = vec![0usize; n_lanes];
        let mut rxs = Vec::new();
        for step in 0..g.usize(20, 80) {
            if g.bool() {
                let lane = g.usize(0, n_lanes - 1);
                let (p, r) = pending_for(&format!("t{lane}"), vec![step as u32], 4);
                let got = q.push(p);
                let total: usize = model.iter().sum();
                if total >= max_queue {
                    prop_assert(
                        got == Err(AdmitError::QueueFull),
                        format!("step {step}: expected QueueFull, got {got:?}"),
                    )?;
                } else if cap > 0 && model[lane] >= cap {
                    prop_assert(
                        got == Err(AdmitError::TenantBusy),
                        format!("step {step}: expected TenantBusy, got {got:?}"),
                    )?;
                } else {
                    prop_assert(got.is_ok(), format!("step {step}: expected Ok, got {got:?}"))?;
                    model[lane] += 1;
                    accepted[lane] += 1;
                    rxs.push(r);
                }
            } else {
                match q.pop(false) {
                    DecodePop::Req(p) => {
                        let lane: usize =
                            p.req.tenant.strip_prefix('t').unwrap().parse().unwrap();
                        prop_assert(model[lane] > 0, format!("step {step}: phantom pop"))?;
                        model[lane] -= 1;
                        popped[lane] += 1;
                    }
                    DecodePop::Empty => {
                        let total: usize = model.iter().sum();
                        prop_assert(
                            total == 0,
                            format!("step {step}: Empty with {total} queued"),
                        )?;
                    }
                    DecodePop::Shutdown => return Err(format!("step {step}: early shutdown")),
                }
            }
        }
        while let DecodePop::Req(p) = q.pop(false) {
            let lane: usize = p.req.tenant.strip_prefix('t').unwrap().parse().unwrap();
            popped[lane] += 1;
        }
        prop_assert(
            popped == accepted,
            format!("conservation: accepted {accepted:?} popped {popped:?}"),
        )
    });
}

// ---------------------------------------- server-level (end to end)

fn toks(n: usize, seed: u64) -> Vec<u32> {
    let mut rng = muxq::data::prng::SplitMix64::new(seed);
    (0..n).map(|_| rng.next_below(32) as u32).collect()
}

#[test]
fn weighted_tenants_share_a_saturated_server_three_to_one() {
    // 6 sessions per tenant, weights a:3 b:1, one decode slot: once the
    // backlog builds, completion order must run ~a,a,a,b. `Done` events
    // carry submit→finish latency; with one serial slot, sorting by
    // latency IS the completion order (all submits land within µs, each
    // session takes ms). Quantum 1 keeps DWRR bursts at single requests.
    let fp = Gpt2Model::test_model(2, 16, 2, 48, 32, 7);
    let steps = 4;
    let srv = GenerationServer::start(
        GenBackend::Fp(fp.clone()),
        GenerationConfig {
            max_live: 1,
            max_new_tokens: steps,
            qos: QosConfig {
                quantum_tokens: 1,
                weights: vec![("a".to_string(), 3), ("b".to_string(), 1)],
                ..QosConfig::default()
            },
            ..Default::default()
        },
    );
    // occupy the single slot with a warmup session while the backlog
    // builds, so DWRR sees BOTH lanes fully queued from its first pick
    // (without it, the first few pops race the submission loop)
    let warm = srv
        .submit(GenerateRequest::greedy(toks(5, 99), steps).with_tenant("warm"))
        .unwrap();
    let mut handles = Vec::new();
    for i in 0..6u64 {
        let req = GenerateRequest::greedy(toks(5, 100 + i), steps).with_tenant("a");
        handles.push(("a", toks(5, 100 + i), srv.submit(req).unwrap()));
    }
    for i in 0..6u64 {
        let req = GenerateRequest::greedy(toks(5, 200 + i), steps).with_tenant("b");
        handles.push(("b", toks(5, 200 + i), srv.submit(req).unwrap()));
    }

    let mut finished = Vec::new(); // (latency, tenant)
    assert!(warm.collect_tokens().is_ok());
    for (tenant, prompt, h) in handles {
        let mut tokens = Vec::new();
        let mut done = None;
        while let Some(ev) = h.recv() {
            match ev {
                TokenEvent::Token { token, .. } => tokens.push(token),
                TokenEvent::Done { generated, latency, .. } => done = Some((generated, latency)),
                TokenEvent::Error(e) => panic!("stream error: {e}"),
            }
        }
        let (generated, latency) = done.expect("missing terminal event");
        assert_eq!(generated, steps);
        // bit-exactness survives multi-tenant interleaving
        let want = fp.session(WrapPolicy::default()).generate_greedy(&prompt, steps).unwrap();
        assert_eq!(tokens, want, "tenant {tenant} stream diverged from solo session");
        finished.push((latency, tenant));
    }
    finished.sort_by_key(|(l, _)| *l);
    let order: Vec<&str> = finished.iter().map(|(_, t)| *t).collect();
    let first8_a = order[..8].iter().filter(|t| **t == "a").count();
    assert!(first8_a >= 5, "3:1 weights: expected ~6 'a' in first 8, got {order:?}");
    let first_b = order.iter().position(|t| *t == "b").unwrap();
    assert!(first_b < 6, "light tenant starved: first 'b' at {first_b} in {order:?}");

    let st = srv.stats();
    assert_eq!(st.completed, 13); // 12 measured + the warmup
    // both lanes generated their full budgets (fairness is about order,
    // never about dropping anyone's tokens)
    let shares = srv.metrics().counters_with_prefix("tokens_tenant_");
    let of = |name: &str| {
        shares.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    };
    assert_eq!(of("tokens_tenant_a"), 6 * steps as u64);
    assert_eq!(of("tokens_tenant_b"), 6 * steps as u64);
    srv.shutdown();
}

#[test]
fn single_tenant_server_completes_in_submission_order() {
    // no weights, one anonymous lane, one decode slot: the pre-QoS FIFO
    // contract end to end — completion order == submission order and
    // every stream equals its solo session
    let fp = Gpt2Model::test_model(2, 16, 2, 48, 32, 7);
    let steps = 3;
    let srv = GenerationServer::start(
        GenBackend::Fp(fp.clone()),
        GenerationConfig { max_live: 1, max_new_tokens: steps, ..Default::default() },
    );
    let handles: Vec<_> = (0..5u64)
        .map(|i| (i, srv.submit(GenerateRequest::greedy(toks(4, 300 + i), steps)).unwrap()))
        .collect();
    let mut finished = Vec::new();
    for (i, h) in handles {
        let mut tokens = Vec::new();
        let mut latency = None;
        while let Some(ev) = h.recv() {
            match ev {
                TokenEvent::Token { token, .. } => tokens.push(token),
                TokenEvent::Done { latency: l, .. } => latency = Some(l),
                TokenEvent::Error(e) => panic!("stream error: {e}"),
            }
        }
        let want =
            fp.session(WrapPolicy::default()).generate_greedy(&toks(4, 300 + i), steps).unwrap();
        assert_eq!(tokens, want, "request {i} diverged from solo session");
        finished.push((latency.expect("no Done"), i));
    }
    finished.sort_by_key(|(l, _)| *l);
    let order: Vec<u64> = finished.iter().map(|(_, i)| *i).collect();
    assert_eq!(order, vec![0, 1, 2, 3, 4], "single lane must stay FIFO");
    srv.shutdown();
}
