//! End-to-end bridge smoke test: tiny jax-exported eval graph, loaded and
//! executed via PJRT, checked against the python-computed golden.
//! Only runs when the /tmp fixtures exist (created by the build probe).
use muxq::data::tensors::TensorFile;
use muxq::runtime::{literal_i32, literal_scalar_f32, to_vec_f32, Engine};

#[test]
fn tiny_eval_roundtrip() {
    let hlo = "/tmp/tiny_eval.hlo.txt";
    if !std::path::Path::new(hlo).exists() {
        eprintln!("skipping: {hlo} missing");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let exe = engine.load_hlo(hlo).unwrap();
    let weights = TensorFile::read("/tmp/tiny_weights.bin").unwrap();
    let mut args = Vec::new();
    for name in weights.sorted_names() {
        args.push(weights.get(name).unwrap().to_literal().unwrap());
    }
    let toks: Vec<i32> = (0..32).map(|i| i % 64).collect();
    args.push(literal_i32(&[2, 16], &toks).unwrap());
    args.push(literal_scalar_f32(8.0));
    args.push(literal_scalar_f32(8.0));
    let out = exe.run(&args).unwrap();
    let nll = to_vec_f32(&out[0]).unwrap()[0];
    let count = to_vec_f32(&out[1]).unwrap()[0];
    println!("nll={nll} count={count}");
    assert_eq!(count, 30.0);
    assert!((nll - 124.39593).abs() < 0.05, "nll {nll} != 124.39593");
}
