//! Runtime integration: every compiled eval variant of sim-small must
//! reproduce the python-computed golden (nll, count) end to end through
//! PJRT — validating HLO export, weight ordering, literal conversion and
//! the per-seq aggregation contract in one shot.

use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::data::tensors::TensorFile;

fn setup() -> Option<(VariantRegistry, TensorFile)> {
    let root = muxq::artifacts_dir();
    let gpath = root.join("goldens").join("eval_sim-small.bin");
    if !gpath.exists() || !root.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let registry = VariantRegistry::open_default().unwrap();
    let goldens = TensorFile::read(gpath).unwrap();
    Some((registry, goldens))
}

#[test]
fn all_eval_variants_match_python_goldens() {
    let Some((registry, goldens)) = setup() else { return };
    let tokens = goldens.get("tokens").unwrap().as_i32().unwrap();
    let mut checked = 0;
    for key in registry.keys() {
        if key.model != "sim-small" || key.kind != "eval" {
            continue;
        }
        let gname = format!("nll/{}", key.tag);
        let Ok(g) = goldens.get(&gname) else { continue };
        let want = g.as_f32().unwrap(); // [sum_nll, count]
        let compiled = registry.get(&key).unwrap();
        let out = compiled.run(&tokens, 8.0, 8.0).unwrap();
        let nll: f32 = out[0].data.iter().sum();
        let count: f32 = out[1].data.iter().sum();
        assert_eq!(count, want[1], "{}: count", key.tag);
        // tolerance: XLA fusion reassociates reductions, and activations
        // sitting exactly at the theta=6 outlier boundary can flip the
        // dynamic mask between eager and compiled execution
        let rel = (nll - want[0]).abs() / want[0].abs().max(1.0);
        assert!(rel < 1e-3, "{}: nll {} vs golden {} (rel {rel})", key.tag, nll, want[0]);
        checked += 1;
    }
    assert!(checked >= 7, "only {checked} variants checked");
}

#[test]
fn bit_sweep_ordering_holds_through_runtime() {
    // lower activation bits must not *improve* perplexity for naive, and
    // muxq must beat naive per-tensor at 6 bits (Table 1's shape) — all
    // through the compiled artifacts.
    let Some((registry, goldens)) = setup() else { return };
    let tokens = goldens.get("tokens").unwrap().as_i32().unwrap();
    let nll_of = |tag: &str, ia: f32| -> f32 {
        let key = VariantKey::eval("sim-small", tag);
        let compiled = registry.get(&key).unwrap();
        let out = compiled.run(&tokens, ia, 8.0).unwrap();
        out[0].data.iter().sum()
    };
    let naive8 = nll_of("naive-pt", 8.0);
    let naive6 = nll_of("naive-pt", 6.0);
    let muxq6 = nll_of("muxq-pt", 6.0);
    let fp16 = nll_of("fp16-pt", 8.0);
    assert!(naive6 > naive8, "naive should degrade with fewer bits");
    assert!(muxq6 < naive6, "muxq should beat naive at 6 bits per-tensor");
    assert!(fp16 <= muxq6 * 1.01, "fp16 is the floor");
}

#[test]
fn logits_variant_runs() {
    let Some((registry, goldens)) = setup() else { return };
    let tokens = goldens.get("tokens").unwrap().as_i32().unwrap();
    let key = VariantKey::logits("sim-small", "muxq-pt");
    if registry.meta(&key).is_none() {
        return;
    }
    let compiled = registry.get(&key).unwrap();
    let out = compiled.run(&tokens, 8.0, 8.0).unwrap();
    let logits = out[0].data.clone();
    assert_eq!(logits.len(), 8 * 128 * 512);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn invalid_token_shape_rejected() {
    let Some((registry, _)) = setup() else { return };
    let key = VariantKey::eval("sim-small", "fp16-pt");
    let compiled = registry.get(&key).unwrap();
    assert!(compiled.run(&[0i32; 17], 8.0, 8.0).is_err());
}
