//! Allocator fuzz layer: random op-streams over [`PagedKv`] caches
//! sharing one small [`KvPool`], checked after EVERY op against naive
//! `VecDeque`-backed reference rings. The reference has no pages, no
//! refcounts, and no sharing — so any aliasing (a COW fork that didn't
//! copy, a GC that freed a live page, a seed that leaked a write
//! channel) shows up as a content mismatch, and any bookkeeping error
//! shows up in the pool conservation invariants:
//!
//! * content: every logical row of every cache bit-equals its reference
//! * conservation: `pages_in_use() <= Σ pages_held()` (sharing only
//!   ever REDUCES physical pages) and `pages_created() <= capacity`
//! * no leaks: dropping every cache returns the pool to zero in-use
//! * refusal, not panic: a failed reserve implies the pool really was
//!   out of pages at that moment
//!
//! Case count is driven by `MUXQ_PROPTEST_CASES` (CI pins 500).

use muxq::gpt2::{KvPool, PagedKv};
use muxq::util::proptest::{prop, prop_assert, Gen};
use std::collections::VecDeque;

type RefRing = VecDeque<(Vec<f32>, Vec<f32>)>;

fn ref_push(r: &mut RefRing, cap: usize, k: Vec<f32>, v: Vec<f32>) {
    if r.len() == cap {
        r.pop_front();
    }
    r.push_back((k, v));
}

/// Every cache must present exactly its reference's rows, and the pool
/// counters must satisfy the conservation inequalities.
fn check_all(pool: &KvPool, caches: &[(PagedKv, RefRing, usize)], op: usize) -> Result<(), String> {
    for (ci, (c, r, _)) in caches.iter().enumerate() {
        prop_assert(
            c.len() == r.len(),
            format!("op {op} cache {ci}: len {} != reference {}", c.len(), r.len()),
        )?;
        for (j, (rk, rv)) in r.iter().enumerate() {
            prop_assert(
                c.k_row(j) == rk.as_slice() && c.v_row(j) == rv.as_slice(),
                format!("op {op} cache {ci} row {j}: content diverged from reference"),
            )?;
        }
    }
    let held: usize = caches.iter().map(|(c, _, _)| c.pages_held()).sum();
    prop_assert(
        pool.pages_in_use() <= held,
        format!("op {op}: {} pages in use but only {held} held (phantom pages)", pool.pages_in_use()),
    )?;
    prop_assert(
        pool.pages_created() <= pool.capacity(),
        format!("op {op}: created {} pages past capacity {}", pool.pages_created(), pool.capacity()),
    )
}

#[test]
fn prop_pool_op_stream_vs_reference() {
    prop("paged caches == VecDeque reference under random op streams", |g| {
        let d = g.usize(1, 4);
        let page_rows = g.usize(1, 4);
        let max_pages = g.usize(2, 12);
        let pool = KvPool::new(max_pages, page_rows, d);
        let n = g.usize(1, 3);
        let mut caches: Vec<(PagedKv, RefRing, usize)> = (0..n)
            .map(|_| {
                let cap = g.usize(1, 10);
                (PagedKv::new(&pool, cap), RefRing::new(), cap)
            })
            .collect();

        let ops = g.usize(20, 60);
        for op in 0..ops {
            let i = g.usize(0, caches.len() - 1);
            match g.usize(0, 9) {
                // push dominates the mix: it exercises alloc, ring
                // overwrite, and the COW choke point all at once
                0..=4 => {
                    let (c, r, cap) = &mut caches[i];
                    match c.ensure_capacity(1) {
                        Ok(()) => {
                            let k = g.vec_f32(d, -4.0, 4.0);
                            let v = g.vec_f32(d, -4.0, 4.0);
                            let wrapped = c.push(&k, &v);
                            prop_assert(
                                wrapped == (r.len() == *cap),
                                format!("op {op}: wrap report disagrees with reference"),
                            )?;
                            ref_push(r, *cap, k, v);
                        }
                        Err(_) => {
                            // refusal must mean genuine exhaustion: the
                            // write page needed allocating and nothing
                            // was free at that moment
                            prop_assert(
                                c.pages_needed(1) > pool.free_pages(),
                                format!(
                                    "op {op}: reserve refused with {} free pages for {} needed",
                                    pool.free_pages(),
                                    c.pages_needed(1)
                                ),
                            )?;
                        }
                    }
                }
                5 => {
                    let want = g.usize(0, 11);
                    let (c, r, _) = &mut caches[i];
                    c.truncate(want);
                    r.truncate(want);
                }
                6 => {
                    let (c, r, _) = &mut caches[i];
                    c.clear();
                    r.clear();
                }
                7 => {
                    // drop & recreate: the dropped table must return its
                    // pages (any leak shows up as in_use > held later)
                    let cap = g.usize(1, 10);
                    caches[i] = (PagedKv::new(&pool, cap), RefRing::new(), cap);
                }
                _ => {
                    // COW fork seed: rebuild cache i from another
                    // cache's page-aligned prefix, zero copies — later
                    // pushes into either owner must fork, never alias
                    if caches.len() < 2 {
                        continue;
                    }
                    let j = (i + 1) % caches.len();
                    let t = caches[j].1.len() / page_rows * page_rows;
                    if t == 0 {
                        continue;
                    }
                    let Some(pages) = caches[j].0.prefix_pages(t) else {
                        continue; // source has wrapped; its prefix is not shareable
                    };
                    let cap = t + g.usize(0, 4);
                    let mut fresh = PagedKv::new(&pool, cap);
                    fresh.seed_prefix(&pages, t).expect("aligned prefix seed is legal");
                    let seeded: RefRing = caches[j].1.iter().take(t).cloned().collect();
                    caches[i] = (fresh, seeded, cap);
                }
            }
            check_all(&pool, &caches, op)?;
        }
        drop(caches);
        prop_assert(
            pool.pages_in_use() == 0,
            format!("dropping every cache left {} pages in use", pool.pages_in_use()),
        )
    });
}

#[test]
fn cow_fork_isolates_and_counts() {
    // directed aliasing check: B seeds A's 4-row prefix, rolls back into
    // the shared range, and overwrites — A must keep its original rows
    // and the pool must record exactly the forks that happened
    let pool = KvPool::new(8, 2, 2);
    let mut a = PagedKv::new(&pool, 6);
    for i in 0..4 {
        let row = vec![i as f32, -(i as f32)];
        a.ensure_capacity(1).unwrap();
        a.push(&row, &row);
    }
    let pages = a.prefix_pages(4).expect("4 rows are page-aligned at 2 rows/page");
    let mut b = PagedKv::new(&pool, 6);
    b.seed_prefix(&pages, 4).unwrap();
    drop(pages);
    assert_eq!(pool.pages_in_use(), 2, "seeding shares pages, it never copies");
    assert_eq!(b.shared_pages(), 2);

    let forks_before = pool.cow_forks();
    b.truncate(1); // row 1 (page 0) becomes B's next write slot
    b.ensure_capacity(1).unwrap(); // forks page 0 away from A
    b.push(&[9.0, 9.0], &[8.0, 8.0]);
    assert_eq!(pool.cow_forks(), forks_before + 1, "one shared page, one fork");
    assert_eq!(b.k_row(1), &[9.0, 9.0]);
    for i in 0..4 {
        assert_eq!(a.k_row(i), &[i as f32, -(i as f32)], "fork leaked into the source cache");
    }
    // page 1 was released by B's truncate; page 0 forked: A's 2 + B's 1
    assert_eq!(pool.pages_in_use(), 3);
}

#[test]
fn free_list_reuse_keeps_created_stable() {
    // churn must recycle buffers, not mint new ones: after the first
    // full fill, `pages_created` is a fixed point across clear/refill
    let pool = KvPool::new(4, 2, 3);
    let mut c = PagedKv::new(&pool, 8);
    let row = [1.0f32, 2.0, 3.0];
    for cycle in 0..5 {
        for _ in 0..8 {
            c.ensure_capacity(1).unwrap();
            c.push(&row, &row);
        }
        assert_eq!(pool.pages_created(), 4, "cycle {cycle} minted fresh pages instead of reusing");
        assert_eq!(pool.pages_in_use(), 4);
        c.clear();
        assert_eq!(pool.pages_in_use(), 0, "cycle {cycle} leaked on clear");
    }
}
