//! Speculative-decoding oracles. Draft-and-verify must be LOSSLESS for
//! greedy decoding — token-for-token equal to the plain incremental
//! session across random models, draft kinds, k, and both FP and
//! true-INT targets — and its rejection rollback must leave the target
//! KV ring bit-identical to a session that never saw the rejected
//! drafts. Losslessness is an exact claim (the verify pass reuses the
//! session oracle rows), so every comparison here is `==`, never an
//! epsilon.

use muxq::gpt2::{
    argmax, DraftKind, Gpt2Model, QuantizedGpt2, Sampler, SessionModel, SessionState,
    SpeculativeSession, SpeculativeState, WrapPolicy,
};
use muxq::quant::EngineSpec;
use muxq::util::proptest::{prop, prop_assert, Gen};

/// Small random model: 1–3 layers, d_head 4–8, n_ctx 8–16, vocab 32.
fn model_for(g: &mut Gen) -> Gpt2Model {
    let n_layer = g.usize(1, 3);
    let n_head = *g.choice(&[1usize, 2, 4]);
    let d_model = n_head * g.usize(4, 8);
    let n_ctx = g.usize(8, 16);
    Gpt2Model::test_model(n_layer, d_model, n_head, n_ctx, 32, g.u64(1, 1 << 30))
}

fn prompt_for(g: &mut Gen, len: usize) -> Vec<u32> {
    (0..len).map(|_| g.usize(0, 31) as u32).collect()
}

fn err_str<T>(r: anyhow::Result<T>) -> Result<T, String> {
    r.map_err(|e| format!("{e:#}"))
}

fn draft_for(g: &mut Gen, n_layer: usize) -> DraftKind {
    if g.bool() {
        DraftKind::NaiveInt8
    } else {
        DraftKind::TruncateLayers(g.usize(1, n_layer))
    }
}

#[test]
fn prop_greedy_spec_lossless_vs_plain() {
    // the tentpole claim: greedy speculation == plain greedy, for every
    // k, both draft kinds, FP and INT targets. Bounds keep both
    // schedules wrap-free (prompt + steps + k <= n_ctx): wrap POINTS
    // differ between spec and plain, losslessness holds inside a window.
    prop("greedy spec == plain greedy", |g| {
        let use_int = g.bool();
        let fp = model_for(g);
        let n_layer = fp.cfg.n_layer;
        let n_ctx = fp.cfg.n_ctx;
        let q;
        let sm = if use_int {
            q = QuantizedGpt2::new(fp, EngineSpec::muxq());
            SessionModel::Int(&q)
        } else {
            q = QuantizedGpt2::new(fp, EngineSpec::naive()); // fp lives inside
            SessionModel::Fp(&q.fp)
        };
        let k = g.usize(1, (n_ctx - 4).min(3));
        let plen = g.usize(1, n_ctx - k - 2);
        let steps = g.usize(1, n_ctx - k - plen);
        let prompt = prompt_for(g, plen);
        let kind = draft_for(g, n_layer);

        let mut plain = SessionState::new(&sm.gpt().cfg, WrapPolicy::default());
        let mut logits = err_str(plain.prefill(sm, &prompt))?;
        let mut want = Vec::new();
        for _ in 0..steps {
            let next = argmax(&logits);
            want.push(next);
            if want.len() < steps {
                logits = err_str(plain.decode_step(sm, next))?;
            }
        }

        let mut spec = err_str(SpeculativeSession::new(sm, kind, k, WrapPolicy::default()))?;
        let got = err_str(spec.generate_greedy(&prompt, steps))?;
        prop_assert(
            got == want,
            format!("int={use_int} {kind:?} k={k} plen={plen} steps={steps}: {got:?} != {want:?}"),
        )?;
        // the accounting must be consistent: every accepted draft is a
        // drafted token, and each round emits accepted/rounds + 1 mean
        let st = &spec.state;
        prop_assert(st.accepted() <= st.drafted(), "accepted > drafted")?;
        if st.rounds() > 0 {
            prop_assert(st.drafted() == st.rounds() * k as u64, "k drafts per round")?;
        }
        Ok(())
    });
}

#[test]
fn prop_rejection_rollback_restores_kv_state() {
    // after any mix of accept/reject rounds, the target session's live
    // window + KV ring must be bit-identical to a fresh session that
    // prefilled the emitted context directly — i.e. rejected drafts
    // leave NO trace. Rounds are driven by hand so the state and the
    // emitted stream stay in lockstep (the generate() wrapper may
    // truncate its RETURN without truncating the session).
    prop("rollback leaves no trace in the target ring", |g| {
        let fp = model_for(g);
        let n_layer = fp.cfg.n_layer;
        let n_ctx = fp.cfg.n_ctx;
        let cfg = fp.cfg.clone();
        let holder = QuantizedGpt2::new(fp, EngineSpec::muxq());
        let sm = if g.bool() { SessionModel::Int(&holder) } else { SessionModel::Fp(&holder.fp) };
        let k = g.usize(1, (n_ctx - 4).min(3));
        let plen = g.usize(1, n_ctx - k - 1);
        let rounds = g.usize(1, (n_ctx - plen) / (k + 1)); // wrap-free
        let prompt = prompt_for(g, plen);
        let kind = draft_for(g, n_layer);
        // a warm sampler stream forces genuine rejections some of the time
        let mut smp =
            if g.bool() { Sampler::greedy() } else { Sampler::new(g.f32(0.6, 1.4), 8, g.u64(1, 1 << 30)) };
        let mut dsm = smp.fork(muxq::gpt2::speculative::DRAFT_SEED_SALT);

        let draft = err_str(muxq::gpt2::DraftModel::build(sm.gpt(), kind))?;
        let mut st = err_str(SpeculativeState::new(&cfg, draft.cfg(), k, WrapPolicy::default()))?;
        let logits = err_str(st.prefill(sm, draft.session_model(), &prompt))?;
        let mut next = smp.sample_in_context(&logits, st.target_state().window());
        let mut ctx = prompt.clone();
        ctx.push(next);
        for _ in 0..rounds {
            let toks = err_str(st.round(sm, draft.session_model(), next, &mut smp, &mut dsm))?;
            next = *toks.last().expect("round emits >= 1 token");
            ctx.extend_from_slice(&toks);
        }

        // the live window is exactly the emitted context minus its last
        // token (the last token is the NEXT decode input, never cached)
        let t = st.target_state();
        prop_assert(
            t.window() == &ctx[..ctx.len() - 1],
            format!("{kind:?} k={k}: window != emitted prefix"),
        )?;
        let mut oracle = SessionState::new(&cfg, WrapPolicy::default());
        err_str(oracle.prefill(sm, &ctx[..ctx.len() - 1]))?;
        for (li, (a, b)) in t.caches().iter().zip(oracle.caches()).enumerate() {
            prop_assert(a.len() == b.len(), format!("layer {li}: ring length"))?;
            for j in 0..a.len() {
                prop_assert(
                    a.k_row(j) == b.k_row(j) && a.v_row(j) == b.v_row(j),
                    format!("layer {li} logical row {j}: ring contents differ"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spec_survives_wrap_past_n_ctx() {
    // generate well past the window: reprefill rollback inside rounds
    // must keep emitting finite, in-vocab tokens at the requested count
    prop("spec generation survives wrap", |g| {
        let m = model_for(g);
        let n_layer = m.cfg.n_layer;
        let n_ctx = m.cfg.n_ctx;
        let k = g.usize(1, (n_ctx - 4).min(3));
        let plen = g.usize(1, n_ctx);
        let steps = n_ctx + g.usize(1, 6); // guaranteed to wrap
        let kind = draft_for(g, n_layer);
        let mut spec = err_str(SpeculativeSession::new(
            SessionModel::Fp(&m),
            kind,
            k,
            WrapPolicy::default(),
        ))?;
        let got = err_str(spec.generate_greedy(&prompt_for(g, plen), steps))?;
        prop_assert(got.len() == steps, format!("{} != {steps} tokens", got.len()))?;
        prop_assert(got.iter().all(|&t| t < 32), "out-of-vocab token emitted")?;
        prop_assert(
            spec.state.target_state().window().len() <= n_ctx,
            "target window exceeded n_ctx",
        )?;
        prop_assert(
            spec.state.target_state().prefills() > 1,
            "must have re-prefilled past n_ctx",
        )
    });
}

#[test]
fn prop_stochastic_spec_reproducible_and_rates_sane() {
    // sampled speculation: same seed -> identical stream; acceptance
    // bookkeeping stays within its definitions
    prop("seeded stochastic spec replays", |g| {
        let m = model_for(g);
        let n_layer = m.cfg.n_layer;
        let n_ctx = m.cfg.n_ctx;
        let k = g.usize(1, (n_ctx - 4).min(3));
        let plen = g.usize(1, n_ctx - k - 2);
        let steps = g.usize(1, n_ctx - k - plen);
        let prompt = prompt_for(g, plen);
        let kind = draft_for(g, n_layer);
        let seed = g.u64(1, 1 << 40);
        let temperature = g.f32(0.5, 1.5);
        let run = || -> Result<(Vec<u32>, f64), String> {
            let mut spec =
                err_str(SpeculativeSession::new(SessionModel::Fp(&m), kind, k, WrapPolicy::default()))?;
            let mut smp = Sampler::new(temperature, 8, seed).with_top_p(0.95);
            let out = err_str(spec.generate(&prompt, steps, &mut smp))?;
            Ok((out, spec.state.accept_rate()))
        };
        let (a, ra) = run()?;
        let (b, rb) = run()?;
        prop_assert(a == b, "same seed must replay the identical stream")?;
        prop_assert(ra == rb, "acceptance bookkeeping must replay too")?;
        prop_assert((0.0..=1.0).contains(&ra), format!("accept rate {ra} out of range"))?;
        prop_assert(a.len() == steps && a.iter().all(|&t| t < 32), "stream shape")
    });
}

#[test]
fn spec_state_counters_cross_check_session_oracle() {
    // deterministic cross-check of the stats identities on a fixed model:
    // tokens_per_round == (accepted + rounds) / rounds, and a self-draft
    // (full-depth truncation) accepts everything
    let m = Gpt2Model::test_model(2, 16, 2, 16, 32, 123);
    let sm = SessionModel::Fp(&m);
    let mut spec =
        SpeculativeSession::new(sm, DraftKind::TruncateLayers(2), 3, WrapPolicy::default())
            .unwrap();
    let out = spec.generate_greedy(&[1, 2, 3, 4], 8).unwrap();
    assert_eq!(out.len(), 8);
    let st = &spec.state;
    assert_eq!(st.accept_rate(), 1.0, "a full-depth draft IS the target");
    assert_eq!(st.tokens_per_round(), 4.0, "k+1 tokens per round at k=3");
    // and the plain session agrees with the emitted stream
    let mut plain = m.session(WrapPolicy::default());
    assert_eq!(plain.generate_greedy(&[1, 2, 3, 4], 8).unwrap(), out);
}

#[test]
fn spec_misconfig_is_rejected_up_front() {
    let m = Gpt2Model::test_model(1, 8, 1, 8, 32, 5);
    let sm = SessionModel::Fp(&m);
    assert!(
        SpeculativeSession::new(sm, DraftKind::NaiveInt8, 0, WrapPolicy::default()).is_err(),
        "k = 0"
    );
    assert!(
        SpeculativeSession::new(sm, DraftKind::NaiveInt8, 2, WrapPolicy::Slide).is_err(),
        "Slide wrap cannot roll back"
    );
    assert!(
        SpeculativeSession::new(sm, DraftKind::TruncateLayers(7), 2, WrapPolicy::default())
            .is_err(),
        "draft deeper than the target"
    );
    assert!(
        SpeculativeSession::new(sm, DraftKind::NaiveInt8, 7, WrapPolicy::default()).is_err(),
        "k + 1 must leave room inside n_ctx"
    );
    // SpeculativeState rejects mismatched wrap policies independently of
    // the session wrapper
    assert!(SpeculativeState::new(&m.cfg, &m.cfg, 2, WrapPolicy::Slide).is_err());
}
