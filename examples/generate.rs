//! Text generation through the quantized serving path: greedy decode via
//! the `logits` variants — demonstrates that the INT8 MUXQ model still
//! produces coherent corpus-like text while naive INT quantization (at
//! low bits) degenerates.
//!
//!     cargo run --release --example generate
//!     cargo run --release --example generate -- --ia-bits 6 --steps 48

use anyhow::Result;
use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::data::bpe::Bpe;
use muxq::util::cli::Cli;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("generate", "greedy decode through quantized variants")
        .opt("model", "sim-small", "model")
        .opt("prompt", "= Kamiro =\n\n", "prompt text")
        .opt("steps", "32", "tokens to generate")
        .opt("ia-bits", "8", "activation bits")
        .parse(&args)?;

    let artifacts = muxq::artifacts_dir();
    let bpe = Bpe::load(artifacts.join("corpus").join("tokenizer.bpe"))?;
    let registry = VariantRegistry::open_default()?;
    let model = p.get("model");
    let steps = p.get_usize("steps")?;
    let ia_bits = p.get_f64("ia-bits")? as f32;

    for tag in ["fp16-pt", "muxq-pt"] {
        let key = VariantKey::logits(model, tag);
        let Some(meta) = registry.meta(&key) else {
            println!("(no logits variant {tag}, skipping)");
            continue;
        };
        let (batch, seq) = (meta.batch, meta.seq);
        let vocab = bpe.vocab_size();
        let compiled = registry.get(&key)?;

        let mut ids: Vec<i32> = bpe.encode(p.get("prompt")).iter().map(|&t| t as i32).collect();
        for _ in 0..steps {
            // right-align the context into a fixed [batch, seq] block
            // (rows 1.. are padding copies of row 0)
            let ctx: Vec<i32> = if ids.len() >= seq {
                ids[ids.len() - seq..].to_vec()
            } else {
                let mut c = vec![0i32; seq - ids.len()];
                c.extend_from_slice(&ids);
                c
            };
            let pos = ids.len().min(seq) - 1; // last real position
            let mut block = Vec::with_capacity(batch * seq);
            for _ in 0..batch {
                block.extend_from_slice(&ctx);
            }
            let out = compiled.run(&block, ia_bits, 8.0)?;
            let logits = &out[0].data; // [B,S,V]
            let row = &logits[pos * vocab..(pos + 1) * vocab];
            let next = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i as i32)
                .unwrap();
            ids.push(next);
        }
        let text = bpe.decode(&ids.iter().map(|&t| t as u32).collect::<Vec<_>>());
        println!("--- {model} [{tag}] ia_bits={ia_bits} ---");
        println!("{text}\n");
    }
    Ok(())
}
