//! Text generation on the incremental-decode session API
//! (`gpt2::session`): prefill the prompt ONCE at its TRUE length, then
//! O(context) decode steps through the per-layer KV caches — replacing
//! the old fixed-shape path that re-ran the full O(S²) forward for every
//! token and left-padded short prompts with token 0 (attention attended
//! over the pad positions, skewing short-prompt logits; sessions take
//! the true prompt length, so that bug is gone by construction).
//!
//! By default each variant's text is replayed against its full-forward
//! oracle (the pre-refactor O(S²) algorithm, minus the pad bug): the
//! session path must produce IDENTICAL tokens while paying per-token
//! cost that does not grow with the number of generated tokens.
//!
//!     cargo run --release --example generate
//!     cargo run --release --example generate -- --method muxq --steps 48
//!     cargo run --release --example generate -- --no-check

use anyhow::Result;
use muxq::data::bpe::Bpe;
use muxq::gpt2::{argmax, DecodeSession, Gpt2Model, IntMethod, QuantizedGpt2, WrapPolicy};
use muxq::util::cli::Cli;
use std::time::Instant;

/// Greedy decode through a session; returns (tokens, prefill_ms,
/// first-half ms/token, second-half ms/token).
fn generate_session(
    sess: &mut DecodeSession<'_>,
    prompt: &[u32],
    steps: usize,
) -> Result<(Vec<u32>, f64, f64, f64)> {
    let t0 = Instant::now();
    let logits = sess.prefill(prompt)?;
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut out = Vec::with_capacity(steps);
    let mut next = argmax(&logits);
    let mut half_ms = [0.0f64; 2];
    let half = steps.div_ceil(2).max(1);
    for i in 0..steps {
        out.push(next);
        if i + 1 == steps {
            break;
        }
        let t = Instant::now();
        let logits = sess.decode_step(next)?;
        half_ms[i / half] += t.elapsed().as_secs_f64() * 1e3;
        next = argmax(&logits);
    }
    let first = half_ms[0] / half.min(steps.saturating_sub(1)).max(1) as f64;
    let second = half_ms[1] / steps.saturating_sub(1 + half).max(1) as f64;
    Ok((out, prefill_ms, first, second))
}

/// The pre-refactor algorithm (full forward per token, O(S²) total) at
/// the session's semantics — the oracle the session must match
/// bit-for-bit while the context fits `n_ctx`.
fn generate_full_oracle(
    fp: &Gpt2Model,
    int: Option<&QuantizedGpt2>,
    prompt: &[u32],
    steps: usize,
) -> Result<(Vec<u32>, f64)> {
    let mut ctx = prompt.to_vec();
    let mut out = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for _ in 0..steps {
        let logits = match int {
            None => fp.forward(&[ctx.clone()], None, None)?,
            Some(q) => q.forward_logits_session(&[ctx.clone()])?,
        };
        let next = argmax(logits.row(ctx.len() - 1));
        out.push(next);
        ctx.push(next);
    }
    let per_tok_ms = t0.elapsed().as_secs_f64() * 1e3 / steps.max(1) as f64;
    Ok((out, per_tok_ms))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("generate", "greedy decode on the KV-cache session API")
        .opt("model", "sim-small", "model (artifacts; falls back to a seeded test model)")
        .opt("prompt", "= Kamiro =\n\n", "prompt text")
        .opt("steps", "32", "tokens to generate")
        .opt("ia-bits", "8", "activation bits for the INT variants")
        .opt("method", "all", "fp32 | naive | muxq | all")
        .flag("no-check", "skip the full-forward oracle replay")
        .parse(&args)?;
    let steps = p.get_usize("steps")?;
    let ia_bits = p.get_f64("ia-bits")? as u32;
    let method = p.get("method").to_string();
    if !["all", "fp32", "naive", "muxq"].contains(&method.as_str()) {
        anyhow::bail!("unknown --method {method:?} (expected fp32 | naive | muxq | all)");
    }
    let check = !p.flag("no-check");

    let artifacts = muxq::artifacts_dir();
    let (fp, bpe) = match Gpt2Model::load_from_artifacts(p.get("model")) {
        Ok(m) => (m, Bpe::load(artifacts.join("corpus").join("tokenizer.bpe")).ok()),
        Err(e) => {
            println!("(no artifacts: {e:#}; using a seeded test model, token-id output)\n");
            (Gpt2Model::test_model(4, 128, 4, 128, 512, 7), None)
        }
    };
    let vocab = fp.cfg.vocab_size as u32;
    let prompt: Vec<u32> = match &bpe {
        Some(b) => b.encode(p.get("prompt")),
        None => p.get("prompt").bytes().map(|b| b as u32 % vocab).collect(),
    };
    println!(
        "model {} (ctx {}), prompt {} tokens, {steps} steps\n",
        fp.cfg.name, fp.cfg.n_ctx, prompt.len()
    );

    let variants: Vec<(&str, Option<IntMethod>)> = vec![
        ("fp32", None),
        ("naive-int8", Some(IntMethod::Naive)),
        ("muxq-int8", Some(IntMethod::Muxq)),
    ];
    for (name, im) in variants {
        let selected = method == "all"
            || match im {
                None => method == "fp32",
                Some(IntMethod::Naive) => method == "naive",
                Some(IntMethod::Muxq) => method == "muxq",
            };
        if !selected {
            continue;
        }
        // the quantized model must outlive the session borrowing it
        let q = im.map(|m| QuantizedGpt2::new(fp.clone(), m, ia_bits, 8));
        let mut sess = match &q {
            None => fp.session(WrapPolicy::default()),
            Some(qq) => qq.session(WrapPolicy::default()),
        };
        let (tokens, prefill_ms, first_ms, second_ms) =
            generate_session(&mut sess, &prompt, steps)?;
        println!("--- {name} (ia_bits {ia_bits}) ---");
        println!(
            "prefill {prefill_ms:.2}ms   decode {first_ms:.3}ms/tok (first half) \
             {second_ms:.3}ms/tok (second half)   re-prefills {}",
            sess.state.prefills().saturating_sub(1)
        );
        match &bpe {
            Some(b) => {
                let mut text: Vec<u32> = prompt.clone();
                text.extend_from_slice(&tokens);
                println!("{}", b.decode(&text));
            }
            None => println!("tokens: {tokens:?}"),
        }
        if check {
            // oracle comparison only while the context fits n_ctx (past
            // that the oracle itself cannot run in one forward)
            let oracle_steps = steps.min(fp.cfg.n_ctx.saturating_sub(prompt.len().min(fp.cfg.n_ctx)));
            if oracle_steps > 0 {
                let (want, full_ms) =
                    generate_full_oracle(&fp, q.as_ref(), &prompt, oracle_steps)?;
                assert_eq!(
                    &tokens[..oracle_steps],
                    &want[..],
                    "{name}: session decode diverged from the full-forward oracle"
                );
                println!(
                    "oracle replay: first {oracle_steps} tokens identical \u{2713}  \
                     (full forward paid {full_ms:.3}ms/tok and grows with length)"
                );
            }
        }
        println!();
    }
    Ok(())
}
