//! Text generation on the incremental-decode session API
//! (`gpt2::session`): prefill the prompt ONCE at its TRUE length (head
//! GEMM for the last row only), then O(context) decode steps through the
//! per-layer KV caches — replacing the old fixed-shape path that re-ran
//! the full O(S²) forward for every token.
//!
//! Every deployed method goes through the one `QuantLinear` operator API
//! (`EngineSpec::parse` of `--method`), so fp32, naive, MUXQ and
//! LLM.int8() — plus `-sq` smoothed compositions — all decode here.
//!
//! By default each variant's text is replayed against its full-forward
//! oracle (the pre-refactor O(S²) algorithm, minus the pad bug): the
//! session path must produce IDENTICAL tokens while paying per-token
//! cost that does not grow with the number of generated tokens. (The
//! oracle replay is greedy-only; sampled runs check seed replay
//! instead.)
//!
//! `--spec` additionally decodes each variant through draft-and-verify
//! speculation (`gpt2::speculative`) and, in greedy mode, asserts the
//! speculative stream equals the plain stream over the wrap-free prefix
//! — the losslessness claim, checked live.
//!
//!     cargo run --release --example generate
//!     cargo run --release --example generate -- --method muxq-pv --steps 48
//!     cargo run --release --example generate -- --temperature 0.9 --top-k 40 --seed 7
//!     cargo run --release --example generate -- --top-p 0.92 --rep-penalty 1.3
//!     cargo run --release --example generate -- --spec --spec-k 3 --draft trunc2
//!     cargo run --release --example generate -- --no-check

use anyhow::Result;
use muxq::data::bpe::Bpe;
use muxq::gpt2::{
    DecodeSession, DraftKind, Gpt2Model, QuantizedGpt2, Sampler, SessionModel,
    SpeculativeSession, WrapPolicy,
};
use muxq::quant::EngineSpec;
use muxq::util::cli::Cli;
use std::time::Instant;

/// Decode through a session; returns (tokens, prefill_ms,
/// first-half ms/token, second-half ms/token).
fn generate_session(
    sess: &mut DecodeSession<'_>,
    sampler: &mut Sampler,
    prompt: &[u32],
    steps: usize,
) -> Result<(Vec<u32>, f64, f64, f64)> {
    let t0 = Instant::now();
    let logits = sess.prefill(prompt)?;
    let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
    let mut out = Vec::with_capacity(steps);
    let mut next = sampler.sample_in_context(&logits, sess.state.window());
    let mut half_ms = [0.0f64; 2];
    let half = steps.div_ceil(2).max(1);
    for i in 0..steps {
        out.push(next);
        if i + 1 == steps {
            break;
        }
        let t = Instant::now();
        let logits = sess.decode_step(next)?;
        half_ms[i / half] += t.elapsed().as_secs_f64() * 1e3;
        next = sampler.sample_in_context(&logits, sess.state.window());
    }
    let first = half_ms[0] / half.min(steps.saturating_sub(1)).max(1) as f64;
    let second = half_ms[1] / steps.saturating_sub(1 + half).max(1) as f64;
    Ok((out, prefill_ms, first, second))
}

/// The pre-refactor algorithm (full forward per token, O(S²) total) at
/// the session's semantics — the greedy oracle the session must match
/// bit-for-bit while the context fits `n_ctx`.
fn generate_full_oracle(
    fp: &Gpt2Model,
    int: Option<&QuantizedGpt2>,
    prompt: &[u32],
    steps: usize,
) -> Result<(Vec<u32>, f64)> {
    let mut ctx = prompt.to_vec();
    let mut out = Vec::with_capacity(steps);
    let t0 = Instant::now();
    for _ in 0..steps {
        let logits = match int {
            None => fp.forward(&[ctx.clone()], None, None)?,
            Some(q) => q.forward_logits_session(&[ctx.clone()])?,
        };
        let next = muxq::gpt2::argmax(logits.row(ctx.len() - 1));
        out.push(next);
        ctx.push(next);
    }
    let per_tok_ms = t0.elapsed().as_secs_f64() * 1e3 / steps.max(1) as f64;
    Ok((out, per_tok_ms))
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("generate", "token generation on the KV-cache session API")
        .opt("model", "sim-small", "model (artifacts; falls back to a seeded test model)")
        .opt("prompt", "= Kamiro =\n\n", "prompt text")
        .opt("steps", "32", "tokens to generate")
        .opt("ia-bits", "8", "activation bits for the INT variants")
        .opt(
            "method",
            "all",
            "fp32 | an EngineSpec tag (naive-pv, muxq-pv, llmint8-pv, muxq-pv-sq, ...) | all",
        )
        .opt("temperature", "0", "softmax temperature (0 = greedy)")
        .opt("top-k", "0", "sample among the k best logits (0 = all)")
        .opt("top-p", "1", "nucleus cutoff (1 = disabled)")
        .opt("rep-penalty", "1", "repetition penalty on seen tokens (1 = disabled)")
        .opt("seed", "0", "sampling seed (replayable streams)")
        .flag("spec", "also decode speculatively (draft-and-verify)")
        .opt("spec-k", "3", "drafts per speculative round")
        .opt("draft", "naive-int8", "draft model: naive-int8 | trunc<N>")
        .flag("no-check", "skip the full-forward oracle replay")
        .parse(&args)?;
    let steps = p.get_usize("steps")?;
    let ia_bits = p.get_f64("ia-bits")? as u32;
    let method = p.get("method").to_string();
    let temperature = p.get_f64("temperature")? as f32;
    let top_k = p.get_usize("top-k")?;
    let top_p = p.get_f64("top-p")? as f32;
    let rep_penalty = p.get_f64("rep-penalty")? as f32;
    let seed = p.get_usize("seed")? as u64;
    let spec = p.flag("spec");
    let spec_k = p.get_usize("spec-k")?;
    let draft_kind = DraftKind::parse(p.get("draft"))?;
    let check = !p.flag("no-check");
    let sampler_for = || {
        Sampler::new(temperature, top_k, seed)
            .with_top_p(top_p)
            .with_repetition_penalty(rep_penalty)
    };
    // let the Sampler define degeneracy (T <= 0 OR top-k == 1), so a
    // run that decodes greedily always gets the real oracle replay
    let greedy = sampler_for().is_greedy();

    let artifacts = muxq::artifacts_dir();
    let (fp, bpe) = match Gpt2Model::load_from_artifacts(p.get("model")) {
        Ok(m) => (m, Bpe::load(artifacts.join("corpus").join("tokenizer.bpe")).ok()),
        Err(e) => {
            println!("(no artifacts: {e:#}; using a seeded test model, token-id output)\n");
            (Gpt2Model::test_model(4, 128, 4, 128, 512, 7), None)
        }
    };
    let vocab = fp.cfg.vocab_size as u32;
    let prompt: Vec<u32> = match &bpe {
        Some(b) => b.encode(p.get("prompt")),
        None => p.get("prompt").bytes().map(|b| b as u32 % vocab).collect(),
    };
    println!(
        "model {} (ctx {}), prompt {} tokens, {steps} steps, {}\n",
        fp.cfg.name,
        fp.cfg.n_ctx,
        prompt.len(),
        if greedy {
            "greedy".to_string()
        } else {
            format!(
                "T={temperature} top-k={top_k} top-p={top_p} rp={rep_penalty} seed={seed}"
            )
        }
    );

    // every variant is an EngineSpec tag; "fp32" is the raw f32 model
    let variants: Vec<String> = if method == "all" {
        vec!["fp32".into(), "naive-pv".into(), "muxq-pv".into(), "llmint8-pv".into()]
    } else {
        vec![method.clone()]
    };
    for name in &variants {
        // the quantized model must outlive the session borrowing it
        let q = if name == "fp32" {
            None
        } else {
            let spec = EngineSpec::parse(name)?.with_bits(ia_bits, 8);
            Some(QuantizedGpt2::new(fp.clone(), spec))
        };
        let mut sess = match &q {
            None => fp.session(WrapPolicy::default()),
            Some(qq) => qq.session(WrapPolicy::default()),
        };
        let mut sampler = sampler_for();
        let (tokens, prefill_ms, first_ms, second_ms) =
            generate_session(&mut sess, &mut sampler, &prompt, steps)?;
        println!("--- {name} (ia_bits {ia_bits}) ---");
        println!(
            "prefill {prefill_ms:.2}ms   decode {first_ms:.3}ms/tok (first half) \
             {second_ms:.3}ms/tok (second half)   re-prefills {}",
            sess.state.prefills().saturating_sub(1)
        );
        match &bpe {
            Some(b) => {
                let mut text: Vec<u32> = prompt.clone();
                text.extend_from_slice(&tokens);
                println!("{}", b.decode(&text));
            }
            None => println!("tokens: {tokens:?}"),
        }
        if check && greedy && rep_penalty == 1.0 {
            // oracle comparison only while the context fits n_ctx (past
            // that the oracle itself cannot run in one forward)
            let oracle_steps =
                steps.min(fp.cfg.n_ctx.saturating_sub(prompt.len().min(fp.cfg.n_ctx)));
            if oracle_steps > 0 {
                let (want, full_ms) =
                    generate_full_oracle(&fp, q.as_ref(), &prompt, oracle_steps)?;
                assert_eq!(
                    &tokens[..oracle_steps],
                    &want[..],
                    "{name}: session decode diverged from the full-forward oracle"
                );
                println!(
                    "oracle replay: first {oracle_steps} tokens identical \u{2713}  \
                     (full forward paid {full_ms:.3}ms/tok and grows with length)"
                );
            }
        } else if check {
            // sampled / penalized runs: the stream must replay exactly
            // from its seed and settings
            let mut sess2 = match &q {
                None => fp.session(WrapPolicy::default()),
                Some(qq) => qq.session(WrapPolicy::default()),
            };
            let replay = sess2.generate(&prompt, steps, &mut sampler_for())?;
            assert_eq!(tokens, replay, "{name}: sampled stream failed to replay from its seed");
            println!("seed replay: {steps} sampled tokens identical \u{2713}");
        }
        if spec {
            // the same variant again, through draft-and-verify
            let smodel = match &q {
                None => SessionModel::Fp(&fp),
                Some(qq) => SessionModel::Int(qq),
            };
            let mut ss =
                SpeculativeSession::new(smodel, draft_kind, spec_k, WrapPolicy::default())?;
            let mut smp = sampler_for();
            let t0 = Instant::now();
            let spec_tokens = ss.generate(&prompt, steps, &mut smp)?;
            let ms_per_tok = t0.elapsed().as_secs_f64() * 1e3 / steps.max(1) as f64;
            println!(
                "spec[k={spec_k} draft={}] {ms_per_tok:.3}ms/tok   accept-rate {:.2}   \
                 tokens/round {:.2}",
                draft_kind.tag(),
                ss.state.accept_rate(),
                ss.state.tokens_per_round(),
            );
            if check && greedy {
                // lossless while BOTH schedules stay wrap-free:
                // prompt + steps + k must fit inside n_ctx
                let lossless = steps.min(
                    fp.cfg.n_ctx.saturating_sub(spec_k).saturating_sub(prompt.len()),
                );
                assert_eq!(
                    &spec_tokens[..lossless],
                    &tokens[..lossless],
                    "{name}: speculative greedy diverged from plain greedy"
                );
                println!("spec lossless: first {lossless} tokens == plain greedy \u{2713}");
            }
        }
        println!();
    }
    Ok(())
}
