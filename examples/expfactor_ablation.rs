//! exp_factor ablation (paper §3.3 trade-off + Fig. 4 lower panel):
//! accuracy and hardware cost as the outlier shift varies.
//!
//! * accuracy: perplexity through the AOT-compiled e1/e2/e3 variants
//!   (sim-small) — larger shifts quantize the Body better but amplify
//!   Aux quantization error by (2^exp − 1).
//! * hardware: npusim plan cost — exp=1 recombines as a plain sum
//!   (concat GEMM), exp>1 may pay a recombination pass.
//!
//!     cargo run --release --example expfactor_ablation

use anyhow::Result;
use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::harness::{eval_ppl, eval_windows, table_windows};
use muxq::npusim::gemm_plan::Plan;
use muxq::npusim::NpuConfig;
use muxq::quant::muxq::{fq_muxq, MuxqParams};
use muxq::quant::{EngineSpec, Granularity, MatF32, Method};

fn main() -> Result<()> {
    // ---- matrix-level error sweep (pure rust engine)
    println!("matrix-level: per-tensor INT8 fake-quant MAE vs exp_factor");
    println!("(256x64, outlier channels x24)\n");
    let mut rng = muxq::data::prng::SplitMix64::new(3);
    let mut x = MatF32::from_vec(
        256,
        64,
        (0..256 * 64).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
    )?;
    for r in 0..x.rows {
        *x.at_mut(r, 5) *= 24.0;
        *x.at_mut(r, 33) *= 24.0;
    }
    println!("{:>10} {:>14} {:>14}", "exp", "MAE(6-bit)", "MAE(8-bit)");
    for exp in [1u32, 2, 3, 4] {
        let p = MuxqParams { theta: 6.0, exp_factor: exp };
        let e6 = fq_muxq(&x, 31.0, Granularity::PerTensor, &p).mean_abs_diff(&x);
        let e8 = fq_muxq(&x, 127.0, Granularity::PerTensor, &p).mean_abs_diff(&x);
        println!("{exp:>10} {e6:>14.5} {e8:>14.5}");
    }

    // ---- model-level perplexity through the compiled ablation variants
    match VariantRegistry::open_default() {
        Ok(registry) => {
            let windows = eval_windows(table_windows())?;
            println!("\nmodel-level: sim-small per-tensor perplexity (IA=6, W=8)");
            println!("{:>10} {:>12}", "exp", "ppl");
            for exp in [1u32, 2, 3] {
                // the canonical tag spells exp_factor itself (-e suffix
                // for non-default values) — no hand-kept tag list
                let spec = EngineSpec::muxq()
                    .with_granularity(Granularity::PerTensor, Granularity::PerTensor)
                    .with_muxq(MuxqParams { theta: 6.0, exp_factor: exp });
                let key = VariantKey::eval("sim-small", &spec.tag());
                if registry.meta(&key).is_none() {
                    continue;
                }
                let ppl = eval_ppl(&registry, &key, 6.0, 8.0, &windows)?;
                println!("{exp:>10} {ppl:>12.4}");
            }
        }
        Err(e) => println!("\n(model-level sweep skipped: {e})"),
    }

    // ---- hardware cost of the recombination choice
    let cfg = NpuConfig::default();
    println!("\nhardware: c_fc projection plan cost (1024x768 @ 768x3072, r=8)");
    println!("{:>10} {:>14} {:>10}", "exp", "cycles", "plan");
    for exp in [1u32, 2, 3] {
        let plan = Plan::build(&cfg, Method::Muxq, 1024, 768, 3072, 8, 8, exp);
        println!(
            "{exp:>10} {:>14.0} {:>10}",
            plan.cost(&cfg).cycles(),
            if plan.gemms.len() == 1 { "concat" } else { "2-GEMM" }
        );
    }
    println!(
        "\nTrade-off (paper §3.3): exp=1 is hardware-simplest (plain sum) but only\n\
         halves outliers; exp=2 (default) balances outlier reduction against Aux\n\
         error amplification; larger exp helps only with extreme outliers."
    );
    Ok(())
}
