//! Table 2 regenerator: weight-precision sweep (IA=8, W ∈ {5, 4}) on the
//! small model, per-vector granularity — the paper's evidence that weight
//! precision does NOT differentiate the outlier-handling methods.
//!
//!     cargo run --release --example table2

use anyhow::Result;
use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::harness::{eval_ppl, eval_windows, fmt_ppl, table_windows};
use muxq::quant::{EngineSpec, Granularity, Method};

fn main() -> Result<()> {
    let registry = VariantRegistry::open_default()?;
    let windows = eval_windows(table_windows())?;
    println!("Table 2: perplexity under different weight-bit settings");
    println!("(sim-small, per-vector, {} validation windows)\n", windows.len());
    println!(
        "{:>3} {:>3} | {:>10} {:>10} {:>10} {:>10}",
        "IA", "W", "naive", "MUXQ", "llm.int8()", "fp16"
    );
    let fp16_tag = EngineSpec::fp16()
        .with_granularity(Granularity::PerTensor, Granularity::PerTensor)
        .tag();
    let fp16 =
        eval_ppl(&registry, &VariantKey::eval("sim-small", &fp16_tag), 8.0, 8.0, &windows)?;
    for w_bits in [5u32, 4] {
        let mut cells = Vec::new();
        for method in [Method::Naive, Method::Muxq, Method::LlmInt8] {
            // per-vector is EngineSpec's deployment default
            let key = VariantKey::eval("sim-small", &EngineSpec::new(method).tag());
            cells.push(eval_ppl(&registry, &key, 8.0, w_bits as f32, &windows)?);
        }
        println!(
            "{:>3} {:>3} | {} {} {} {}",
            8,
            w_bits,
            fmt_ppl(cells[0]),
            fmt_ppl(cells[1]),
            fmt_ppl(cells[2]),
            fmt_ppl(fp16)
        );
    }
    println!(
        "\nExpected shape (paper Table 2): all three methods degrade by a similar\n\
         magnitude as W bits drop — weight precision is not where the methods differ."
    );
    Ok(())
}
