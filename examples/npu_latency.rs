//! Hardware-efficiency study (paper §4.5 + Fig. 4 comparison): prices
//! each method's execution plan on the NPU cost model — the experiment
//! the paper leaves as future work.
//!
//!     cargo run --release --example npu_latency

use anyhow::Result;
use muxq::gpt2::{Gpt2Model, QuantizedGpt2};
use muxq::npusim::gemm_plan::Plan;
use muxq::npusim::report::{compare, paper_geometries, render_table, sim_geometries};
use muxq::npusim::NpuConfig;
use muxq::quant::{EngineSpec, Method};

fn main() -> Result<()> {
    let cfg = NpuConfig::default();
    println!(
        "NPU cost model: {}x{} INT8 systolic array @ {} GHz, {} GB/s DRAM,\n\
         FP16 at 1/{}x MAC rate, gather at {} B/cycle, domain switch {} cycles\n",
        cfg.array_dim,
        cfg.array_dim,
        cfg.freq_ghz,
        cfg.dram_gbps,
        cfg.fp16_slowdown,
        cfg.gather_bytes_per_cycle,
        cfg.domain_switch_cycles
    );

    println!("== paper GPT-2 geometries (batch*seq = 1024 tokens) ==");
    let mut rows = Vec::new();
    for (name, g) in paper_geometries() {
        rows.extend(compare(&cfg, name, g, 8));
    }
    println!("{}", render_table(&rows));

    println!("== sim models shipped in artifacts/ ==");
    let mut rows = Vec::new();
    for (name, g) in sim_geometries() {
        rows.extend(compare(&cfg, name, g, 8));
    }
    println!("{}", render_table(&rows));

    println!("== INT4 activations (the paper's INT4 outlook) ==");
    let mut rows = Vec::new();
    for (name, g) in paper_geometries() {
        rows.extend(compare(&cfg, name, g, 4));
    }
    println!("{}", render_table(&rows));

    // per-projection plan breakdown: where llm.int8() loses
    println!("== per-projection plan (gpt2-small c_fc: 1024x768 @ 768x3072, r=8) ==");
    println!(
        "{:<12} {:>12} {:>22} {:>18}",
        "method", "cycles", "plan", "non-uniform frac"
    );
    for method in [Method::Fp16, Method::Naive, Method::Muxq, Method::LlmInt8] {
        let plan = Plan::build(&cfg, method, 1024, 768, 3072, 8, 8, 2);
        let desc: Vec<String> =
            plan.gemms.iter().map(|g| format!("{}[k={}]", g.label, g.k)).collect();
        println!(
            "{:<12} {:>12.0} {:>22} {:>17.1}%",
            method.name(),
            plan.cost(&cfg).cycles(),
            desc.join("+"),
            plan.non_uniform_fraction(&cfg) * 100.0
        );
    }
    println!(
        "\nShape to observe: naive INT8 ~{}x faster than FP16; MUXQ within a few\n\
         percent of naive (skinny aux concat); LLM.int8() loses its INT advantage\n\
         to the FP16 outlier GEMM + gather/scatter + pipeline domain switches.",
        NpuConfig::default().fp16_slowdown
    );

    // ---- object-level pricing: the SAME deployed operators that serve
    // tokens (QuantLinear::plan) price one decode step per method
    println!("\n== deployed-model decode step (sim-small shapes, r=6, via QuantLinear::plan) ==");
    println!("{:<12} {:>12} {:>14}", "spec", "cycles", "sim tok/s");
    let fp = Gpt2Model::test_model(4, 128, 4, 128, 512, 7);
    for spec in [EngineSpec::naive(), EngineSpec::muxq(), EngineSpec::llmint8()] {
        let q = QuantizedGpt2::new(fp.clone(), spec);
        let cost = q.decode_cost_sim(&cfg, 6);
        let us = cost.latency_us(&cfg);
        println!(
            "{:<12} {:>12.0} {:>14.0}",
            spec.tag(),
            cost.cycles(),
            if us > 0.0 { 1e6 / us } else { 0.0 }
        );
    }
    Ok(())
}
