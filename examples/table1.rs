//! Table 1 regenerator: perplexity under different quantization settings.
//!
//! Paper rows: GPT2-small per-vector IA∈{8,7,6,5} W=8 + per-tensor (8,8);
//! GPT2-medium/large per-tensor IA∈{8,7,6} W=8. Columns: naive, MUXQ,
//! LLM.int8(), FP16. Models are the sim-scale stand-ins (DESIGN.md §2);
//! absolute perplexities differ from the paper's pretrained checkpoints,
//! the *shape* (who wins, where naive blows up) is the reproduction
//! target.
//!
//!     cargo run --release --example table1
//!     MUXQ_EVAL_WINDOWS=8 cargo run --release --example table1   # quick

use anyhow::Result;
use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::harness::{eval_ppl, eval_windows, fmt_ppl, table_windows};
use muxq::quant::{EngineSpec, Granularity, Method};

fn main() -> Result<()> {
    let registry = VariantRegistry::open_default()?;
    let windows = eval_windows(table_windows())?;
    println!("Table 1: perplexity comparison under different quantization settings");
    println!("({} validation windows; sim-scale models, see DESIGN.md §2)\n", windows.len());
    println!(
        "{:<12} {:<12} {:>3} {:>3} | {:>10} {:>10} {:>10} {:>10}",
        "model", "granularity", "IA", "W", "naive", "MUXQ", "llm.int8()", "fp16"
    );

    let rows: Vec<(&str, &str, Vec<(u32, u32)>)> = vec![
        ("sim-small", "per-vector", vec![(8, 8), (7, 8), (6, 8), (5, 8)]),
        ("sim-small", "per-tensor", vec![(8, 8)]),
        ("sim-medium", "per-tensor", vec![(8, 8), (7, 8), (6, 8)]),
        ("sim-large", "per-tensor", vec![(8, 8), (7, 8), (6, 8)]),
    ];

    for (model, gran, bit_rows) in rows {
        // canonical tags from EngineSpec — the same spelling the
        // manifest validates and the deployed pipeline uses
        let spec_at = |m: Method| {
            let (a, w) = Granularity::parse(gran).expect("table granularity");
            EngineSpec::new(m).with_granularity(a, w)
        };
        let fp16 = eval_ppl(
            &registry,
            &VariantKey::eval(
                model,
                &EngineSpec::fp16()
                    .with_granularity(Granularity::PerTensor, Granularity::PerTensor)
                    .tag(),
            ),
            8.0,
            8.0,
            &windows,
        )?;
        for (ia, w) in bit_rows {
            let mut cells = Vec::new();
            for method in [Method::Naive, Method::Muxq, Method::LlmInt8] {
                let key = VariantKey::eval(model, &spec_at(method).tag());
                cells.push(eval_ppl(&registry, &key, ia as f32, w as f32, &windows)?);
            }
            println!(
                "{:<12} {:<12} {:>3} {:>3} | {} {} {} {}",
                model,
                gran,
                ia,
                w,
                fmt_ppl(cells[0]),
                fmt_ppl(cells[1]),
                fmt_ppl(cells[2]),
                fmt_ppl(fp16)
            );
        }
    }
    println!(
        "\nExpected shape (paper Table 1): naive degrades sharply as IA bits drop;\n\
         MUXQ tracks LLM.int8() closely while staying uniform-INT; fp16 is the floor."
    );
    Ok(())
}
