//! End-to-end serving driver (the repo's E2E validation; dataflow in
//! DESIGN.md §5):
//! starts the coordinator over the AOT artifacts, generates a realistic
//! scoring workload from the synthetic corpus, drives it through the
//! dynamic batcher from concurrent client threads, and reports perplexity
//! + latency/throughput, comparing quantization methods end to end.
//!
//!     cargo run --release --example serve
//!     cargo run --release --example serve -- --requests 128 --clients 16

use anyhow::Result;
use muxq::coordinator::{Coordinator, CoordinatorConfig, ScoreRequest, VariantKey};
use muxq::data::eval_set::{perplexity, EvalSet};
use muxq::quant::{EngineSpec, Granularity};
use muxq::util::cli::Cli;
use std::sync::Arc;
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("serve", "end-to-end serving driver")
        .opt("model", "sim-small", "model to serve")
        .opt("requests", "64", "requests per method")
        .opt("clients", "8", "concurrent client threads")
        .opt("ia-bits", "8", "activation bits")
        .parse(&args)?;
    let model = p.get("model").to_string();
    let n_requests = p.get_usize("requests")?;
    let n_clients = p.get_usize("clients")?.max(1);
    let ia_bits = p.get_f64("ia-bits")? as f32;

    let artifacts = muxq::artifacts_dir();
    let mut cfg = CoordinatorConfig::default();
    cfg.batcher.max_wait = std::time::Duration::from_millis(10);
    let coord = Arc::new(Coordinator::start(&artifacts, cfg)?);
    let eval = EvalSet::load(&artifacts, "valid")?;
    let windows = Arc::new(eval.windows(128, 0));
    println!(
        "serving {model}: {} validation windows, {n_clients} clients, \
         {n_requests} requests/method\n",
        windows.len()
    );
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
        "variant", "ppl", "req/s", "tok/s", "p50", "p95", "batchfill"
    );

    // canonical tags via EngineSpec (no ad-hoc strings — the same
    // spelling the manifest round-trips)
    let pt = |s: EngineSpec| s.with_granularity(Granularity::PerTensor, Granularity::PerTensor);
    let specs = [
        pt(EngineSpec::fp16()),
        pt(EngineSpec::naive()),
        pt(EngineSpec::muxq()),
        pt(EngineSpec::llmint8()),
        EngineSpec::muxq(),
    ];
    for spec in specs {
        let tag = spec.tag();
        let variant = VariantKey::eval(&model, &tag);
        if coord.manifest().meta(&variant).is_none() {
            continue;
        }
        // warm up compilation outside the timed section
        coord.score(ScoreRequest {
            variant: variant.clone(),
            tokens: windows[0].clone(),
            ia_bits,
            w_bits: 8.0,
        })?;

        let batches_before = coord.stats().batches;
        let completed_before = coord.stats().completed;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for client in 0..n_clients {
            let coord = coord.clone();
            let windows = windows.clone();
            let variant = variant.clone();
            handles.push(std::thread::spawn(move || -> Result<Vec<(f32, f32, f64)>> {
                let mut out = Vec::new();
                // round-robin split of the request stream across clients
                for i in (client..n_requests).step_by(n_clients) {
                    let w = &windows[i % windows.len()];
                    let t = Instant::now();
                    let resp = coord.score(ScoreRequest {
                        variant: variant.clone(),
                        tokens: w.clone(),
                        ia_bits,
                        w_bits: 8.0,
                    })?;
                    out.push((resp.nll, resp.count, t.elapsed().as_secs_f64()));
                }
                Ok(out)
            }));
        }
        let mut all: Vec<(f32, f32, f64)> = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap()?);
        }
        let wall = t0.elapsed().as_secs_f64();
        let pairs: Vec<(f32, f32)> = all.iter().map(|(n, c, _)| (*n, *c)).collect();
        let mut lats: Vec<f64> = all.iter().map(|(_, _, l)| *l).collect();
        lats.sort_by(f64::total_cmp);
        let tokens: f32 = pairs.iter().map(|(_, c)| c).sum();
        let batches = coord.stats().batches - batches_before;
        let completed = coord.stats().completed - completed_before;
        println!(
            "{:<22} {:>10.4} {:>10.1} {:>10.0} {:>9.0}ms {:>9.0}ms {:>9.1}",
            format!("{model}[{tag}]"),
            perplexity(&pairs),
            all.len() as f64 / wall,
            tokens as f64 / wall,
            lats[lats.len() / 2] * 1e3,
            lats[(lats.len() * 95 / 100).min(lats.len() - 1)] * 1e3,
            completed as f64 / batches.max(1) as f64,
        );
    }

    println!("\ncoordinator metrics:\n{}", coord.metrics().render());
    Ok(())
}
