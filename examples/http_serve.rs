//! Stand up the HTTP serving front end (`muxq::serve`) over a
//! generation server and leave it listening — the interactive twin of
//! the stress harness (`examples/stress.rs`).
//!
//!     cargo run --release --example http_serve
//!     cargo run --release --example http_serve -- --addr 127.0.0.1:8080 --method muxq-pv
//!     cargo run --release --example http_serve -- --tenants a:3,b:1 --tenant-cap 8
//!     cargo run --release --example http_serve -- --smoke     # CI: one loopback
//!                                                             # completion, then exit
//!
//! Then talk to it with curl (prompts are token IDs — see the serve
//! module docs for the full wire format):
//!
//!     curl -N http://127.0.0.1:8080/v1/completions \
//!       -d '{"prompt": [1, 2, 3], "max_tokens": 16, "tenant": "a"}'
//!     curl http://127.0.0.1:8080/v1/models
//!     curl http://127.0.0.1:8080/metrics
//!
//! `--smoke` is the CI leg (`rust/scripts/ci_check.sh`): ephemeral
//! port, one streamed completion over loopback asserted token-exact
//! against a solo `DecodeSession`, clean shutdown, exit 0.

use anyhow::{anyhow, Result};
use muxq::coordinator::{GenBackend, GenerationConfig, GenerationServer, QosConfig};
use muxq::gpt2::{Gpt2Model, QuantizedGpt2, WrapPolicy};
use muxq::quant::EngineSpec;
use muxq::serve::{HttpServer, ServeConfig};
use muxq::util::cli::Cli;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

/// Parse `a:3,b:1` into QoS weights.
fn parse_tenants(s: &str) -> Result<Vec<(String, usize)>> {
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|part| {
            let (name, w) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("tenant spec {part:?} is not name:weight"))?;
            Ok((name.to_string(), w.parse::<usize>()?))
        })
        .collect()
}

/// One streamed completion over loopback; returns the token stream.
fn loopback_completion(addr: std::net::SocketAddr, body: &str) -> Result<Vec<u32>> {
    let mut s = TcpStream::connect(addr)?;
    write!(
        s,
        "POST /v1/completions HTTP/1.1\r\nHost: smoke\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )?;
    let mut r = BufReader::new(s);
    let mut status = String::new();
    r.read_line(&mut status)?;
    if !status.starts_with("HTTP/1.1 200") {
        return Err(anyhow!("unexpected status: {}", status.trim()));
    }
    let mut tokens = Vec::new();
    let mut done = false;
    for line in r.lines() {
        let line = line?;
        if let Some(rest) = line.strip_prefix("data: ") {
            if rest == "[DONE]" {
                done = true;
                break;
            }
            let j = muxq::util::json::Json::parse(rest)?;
            if let Ok(t) = j.get("token") {
                tokens.push(t.as_usize()? as u32);
            } else if j.get("finish").is_err() {
                return Err(anyhow!("stream error event: {rest}"));
            }
        }
    }
    if !done {
        return Err(anyhow!("stream ended without data: [DONE]"));
    }
    Ok(tokens)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("http_serve", "HTTP serving front end over the generation server")
        .opt("addr", "127.0.0.1:8080", "bind address (port 0 = ephemeral)")
        .opt("method", "muxq-pv", "fp32 | an EngineSpec tag (naive-pv, muxq-pv, ...)")
        .opt("workers", "16", "HTTP worker threads (max concurrent connections)")
        .opt("max-live", "8", "decode batch width ceiling")
        .opt("max-new", "64", "server-side token budget ceiling")
        .opt("pool-pages", "0", "paged KV pool capacity (0 = ring per session)")
        .opt("tenants", "", "QoS weights, e.g. a:3,b:1 (empty = weight 1 for all)")
        .opt("tenant-cap", "0", "max in-flight sessions per tenant (0 = unlimited)")
        .flag("smoke", "CI mode: one loopback completion, verify, exit")
        .parse(&args)?;
    let smoke = p.flag("smoke");
    let method = p.get("method").to_string();

    // no artifacts needed: a seeded test model serves token IDs
    let fp = Gpt2Model::test_model(2, 32, 2, 48, 64, 7);
    let mut qos = QosConfig {
        max_inflight_per_tenant: p.get_usize("tenant-cap")?,
        ..QosConfig::default()
    };
    qos.weights = parse_tenants(p.get("tenants"))?;
    let gen_cfg = GenerationConfig {
        max_live: p.get_usize("max-live")?,
        max_new_tokens: p.get_usize("max-new")?,
        pool_pages: p.get_usize("pool-pages")?,
        wrap: WrapPolicy::default(),
        qos,
        ..Default::default()
    };
    let (backend, tag) = if method == "fp32" {
        (GenBackend::Fp(fp.clone()), "fp32".to_string())
    } else {
        let spec = EngineSpec::parse(&method)?;
        (GenBackend::Int(QuantizedGpt2::new(fp.clone(), spec)), spec.tag())
    };
    let gen = Arc::new(GenerationServer::start(backend, gen_cfg));
    let serve_cfg = ServeConfig {
        addr: if smoke { "127.0.0.1:0".to_string() } else { p.get("addr").to_string() },
        workers: p.get_usize("workers")?,
        model_id: fp.cfg.name.clone(),
        engine_tag: tag,
        ..Default::default()
    };
    let srv = HttpServer::start(gen.clone(), serve_cfg)?;
    let addr = srv.addr();

    if smoke {
        // the served stream must equal a solo greedy session bit for bit
        let prompt: Vec<u32> = vec![1, 2, 3, 4];
        let steps = 8;
        let want = if method == "fp32" {
            fp.session(WrapPolicy::default()).generate_greedy(&prompt, steps)?
        } else {
            let q = QuantizedGpt2::new(fp.clone(), EngineSpec::parse(&method)?);
            q.session(WrapPolicy::default()).generate_greedy(&prompt, steps)?
        };
        let body = format!(
            "{{\"prompt\": [1, 2, 3, 4], \"max_tokens\": {steps}, \"tenant\": \"smoke\"}}"
        );
        let got = loopback_completion(addr, &body)?;
        if got != want {
            return Err(anyhow!("smoke stream {got:?} != solo session {want:?}"));
        }
        let st = gen.stats();
        srv.shutdown();
        println!(
            "serve smoke OK: {} tokens streamed over {addr}, bit-exact vs solo session \
             (completed {}, tokens {})",
            got.len(),
            st.completed,
            st.tokens_generated
        );
        return Ok(());
    }

    println!("model {} ({}) listening on http://{addr}", fp.cfg.name, method);
    println!("  curl -N http://{addr}/v1/completions -d '{{\"prompt\": [1,2,3], \"max_tokens\": 16}}'");
    println!("  curl http://{addr}/v1/models");
    println!("  curl http://{addr}/metrics");
    println!("(ctrl-c to stop)");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}
