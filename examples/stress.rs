//! Sustained-load stress harness for the HTTP serving front end
//! (`muxq::serve`): hundreds of concurrent loopback connections firing
//! mixed traffic — plain streamed completions, speculative sessions,
//! buffered calls, and deliberate mid-stream disconnects — against one
//! server, with multi-tenant QoS weights under saturation.
//!
//! Reported per run: p50/p99 time-to-first-token, p50/p99 per-token gap,
//! aggregate tokens/s, refusals by class (inline pool shed, queue-full
//! 503, per-tenant 429), server-side cancels for the abandoned streams,
//! KV-pool evictions, and the per-tenant served-token split (the DWRR
//! weights should show up as the share ratio once both lanes saturate).
//! The npusim [`ServeTickPlan`] prices the same multi-tenant decode tick
//! on the modeled NPU and reports the predicted utilization at the
//! measured token rate next to the host numbers.
//!
//!     cargo run --release --example stress
//!     cargo run --release --example stress -- --conns 400 --rounds 3
//!     cargo run --release --example stress -- --tenants a:3,b:1 --steps 24
//!     cargo run --release --example stress -- --json BENCH_serve.json
//!
//! `--json` writes the machine-readable record `bench_check.sh` gates
//! against the committed `BENCH_serve.json` baseline (tokens/s and p99
//! TTFT, anti-ratchet — see the script).
//!
//! [`ServeTickPlan`]: muxq::npusim::gemm_plan::ServeTickPlan

use anyhow::{anyhow, Result};
use muxq::coordinator::{GenBackend, GenerationConfig, GenerationServer, QosConfig};
use muxq::gpt2::{Gpt2Model, QuantizedGpt2};
use muxq::npusim::gemm_plan::ServeTickPlan;
use muxq::npusim::NpuConfig;
use muxq::quant::{EngineSpec, Method};
use muxq::serve::{HttpServer, ServeConfig};
use muxq::util::cli::Cli;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Barrier};
use std::time::Instant;

/// What one client connection did.
#[derive(Debug, Default, Clone)]
struct Outcome {
    /// HTTP status answered (0 = connect/io failure before a status)
    status: u16,
    tokens: usize,
    /// ms to the first streamed token (< 0 = never saw one)
    ttft_ms: f64,
    /// inter-token gaps, ms
    gaps_ms: Vec<f64>,
    /// this client abandoned its stream on purpose
    cancelled: bool,
    finish: String,
}

/// The traffic mix, decided per client index (deterministic).
#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Plain,
    Speculative,
    Buffered,
    Cancel,
}

fn mode_for(i: usize, spec_pct: usize, cancel_pct: usize, buffered_pct: usize) -> Mode {
    let slot = i % 100;
    if slot < spec_pct {
        Mode::Speculative
    } else if slot < spec_pct + cancel_pct {
        Mode::Cancel
    } else if slot < spec_pct + cancel_pct + buffered_pct {
        Mode::Buffered
    } else {
        Mode::Plain
    }
}

/// One client: connect, fire, read the stream, classify the outcome.
fn run_client(addr: SocketAddr, body: &str, mode: Mode) -> Outcome {
    let mut out = Outcome { ttft_ms: -1.0, ..Default::default() };
    let t0 = Instant::now();
    let mut s = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => return out,
    };
    let raw = format!(
        "POST /v1/completions HTTP/1.1\r\nHost: stress\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    if s.write_all(raw.as_bytes()).is_err() {
        return out;
    }
    let mut r = BufReader::new(s);
    let mut status_line = String::new();
    if r.read_line(&mut status_line).is_err() || status_line.len() < 12 {
        return out;
    }
    out.status = status_line[9..12].parse().unwrap_or(0);
    if out.status != 200 {
        return out; // refused (429/503/...); body not needed
    }
    if mode == Mode::Buffered {
        // one fixed-length JSON answer; TTFT is the full response time
        let mut rest = String::new();
        use std::io::Read;
        if r.read_to_string(&mut rest).is_err() {
            return out;
        }
        out.ttft_ms = t0.elapsed().as_secs_f64() * 1e3;
        if let Some(pos) = rest.find("\r\n\r\n") {
            if let Ok(j) = muxq::util::json::Json::parse(rest[pos..].trim()) {
                out.tokens = j.get("generated").and_then(|g| g.as_usize()).unwrap_or(0);
            }
        }
        out.finish = "buffered".into();
        return out;
    }
    let mut last_tok = t0;
    let mut line = String::new();
    loop {
        line.clear();
        match r.read_line(&mut line) {
            Ok(0) | Err(_) => break,
            Ok(_) => {}
        }
        let Some(data) = line.trim_end().strip_prefix("data: ") else {
            continue; // chunk framing / blank separators
        };
        if data == "[DONE]" {
            break;
        }
        if data.starts_with("{\"index\"") {
            let now = Instant::now();
            if out.tokens == 0 {
                out.ttft_ms = (now - t0).as_secs_f64() * 1e3;
            } else {
                out.gaps_ms.push((now - last_tok).as_secs_f64() * 1e3);
            }
            last_tok = now;
            out.tokens += 1;
            if mode == Mode::Cancel {
                out.cancelled = true;
                out.finish = "client-cancel".into();
                return out; // drop the socket mid-stream
            }
        } else if let Some(rest) = data.strip_prefix("{\"finish\":\"") {
            out.finish = rest.split('"').next().unwrap_or("?").to_string();
        }
    }
    out
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn parse_tenants(s: &str) -> Result<Vec<(String, usize)>> {
    s.split(',')
        .map(|part| {
            let (name, w) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("tenant spec {part:?} is not name:weight"))?;
            Ok((name.to_string(), w.parse::<usize>()?))
        })
        .collect()
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let p = Cli::new("stress", "sustained-load stress harness for the HTTP front end")
        .opt("conns", "200", "concurrent connections per round")
        .opt("rounds", "2", "back-to-back waves of connections")
        .opt("steps", "12", "tokens requested per completion")
        .opt("workers", "48", "HTTP worker threads")
        .opt("backlog", "64", "accepted-connection backlog before inline 503 shed")
        .opt("max-live", "8", "decode batch width ceiling")
        .opt("max-queue", "128", "admission queue cap (503 past it)")
        .opt("tenants", "a:3,b:1", "QoS weights, e.g. a:3,b:1")
        .opt("tenant-queue-cap", "48", "per-tenant queued-request cap (429 past it)")
        .opt("spec-pct", "15", "percent of clients decoding speculatively")
        .opt("cancel-pct", "10", "percent of clients abandoning mid-stream")
        .opt("buffered-pct", "10", "percent of clients using stream:false")
        .opt("pool-pages", "96", "paged KV pool capacity (0 = ring per session)")
        .opt("json", "", "write the machine-readable record here (bench gate)")
        .parse(&args)?;
    let conns = p.get_usize("conns")?;
    let rounds = p.get_usize("rounds")?.max(1);
    let steps = p.get_usize("steps")?.max(1);
    let spec_pct = p.get_usize("spec-pct")?;
    let cancel_pct = p.get_usize("cancel-pct")?;
    let buffered_pct = p.get_usize("buffered-pct")?;
    let tenants = parse_tenants(p.get("tenants"))?;

    // tiny seeded model: the harness measures the serving plane, not the
    // GEMM kernels (bench_gemm owns those)
    let fp = Gpt2Model::test_model(2, 32, 2, 48, 64, 7);
    let vocab = fp.cfg.vocab_size as u32;
    let gen = Arc::new(GenerationServer::start(
        GenBackend::Int(QuantizedGpt2::new(fp.clone(), EngineSpec::muxq())),
        GenerationConfig {
            max_live: p.get_usize("max-live")?,
            max_queue: p.get_usize("max-queue")?,
            max_new_tokens: steps,
            pool_pages: p.get_usize("pool-pages")?,
            page_rows: 4,
            qos: QosConfig {
                weights: tenants.clone(),
                max_queue_per_tenant: p.get_usize("tenant-queue-cap")?,
                ..QosConfig::default()
            },
            ..Default::default()
        },
    ));
    let srv = HttpServer::start(
        gen.clone(),
        ServeConfig {
            workers: p.get_usize("workers")?,
            backlog: p.get_usize("backlog")?,
            model_id: fp.cfg.name.clone(),
            engine_tag: EngineSpec::muxq().tag(),
            ..Default::default()
        },
    )?;
    let addr = srv.addr();
    println!(
        "stress: {conns} conns x {rounds} rounds vs {addr}  \
         (mix: {spec_pct}% spec, {cancel_pct}% cancel, {buffered_pct}% buffered; \
         tenants {})",
        p.get("tenants")
    );

    let mut outcomes: Vec<Outcome> = Vec::with_capacity(conns * rounds);
    let t_all = Instant::now();
    for round in 0..rounds {
        let barrier = Arc::new(Barrier::new(conns));
        let handles: Vec<_> = (0..conns)
            .map(|i| {
                let barrier = barrier.clone();
                let tenant = tenants[i % tenants.len()].0.clone();
                let mode = mode_for(i, spec_pct, cancel_pct, buffered_pct);
                // deterministic per-client prompt, 4..8 tokens
                let n = 4 + (i + round) % 4;
                let prompt: Vec<String> = (0..n)
                    .map(|j| (((i * 7 + j * 13 + round) as u32) % vocab).to_string())
                    .collect();
                let mut body = format!(
                    "{{\"prompt\": [{}], \"max_tokens\": {steps}, \"tenant\": \"{tenant}\"",
                    prompt.join(", ")
                );
                match mode {
                    Mode::Speculative => {
                        body.push_str(", \"speculative\": {\"k\": 2, \"draft\": \"naive-int8\"}")
                    }
                    Mode::Buffered => body.push_str(", \"stream\": false"),
                    _ => {}
                }
                body.push('}');
                std::thread::spawn(move || {
                    barrier.wait(); // everyone connects at once
                    run_client(addr, &body, mode)
                })
            })
            .collect();
        for h in handles {
            outcomes.push(h.join().expect("client thread panicked"));
        }
    }
    let wall_s = t_all.elapsed().as_secs_f64();

    // ---- aggregate
    let served = outcomes.iter().filter(|o| o.status == 200 && !o.cancelled).count();
    let refused_429 = outcomes.iter().filter(|o| o.status == 429).count();
    let refused_503 = outcomes.iter().filter(|o| o.status == 503).count();
    let io_errors = outcomes.iter().filter(|o| o.status == 0).count();
    let client_cancels = outcomes.iter().filter(|o| o.cancelled).count();
    let tokens_total: usize = outcomes.iter().map(|o| o.tokens).sum();
    let tok_s = tokens_total as f64 / wall_s.max(1e-9);
    let mut ttfts: Vec<f64> =
        outcomes.iter().filter(|o| o.ttft_ms >= 0.0).map(|o| o.ttft_ms).collect();
    ttfts.sort_by(|a, b| a.total_cmp(b));
    let mut gaps: Vec<f64> = outcomes.iter().flat_map(|o| o.gaps_ms.iter().copied()).collect();
    gaps.sort_by(|a, b| a.total_cmp(b));
    let st = gen.stats();
    let sheds = gen.metrics().counter("http_sheds").get();
    let by_tenant = gen.metrics().counters_with_prefix("tokens_tenant_");

    println!("\n---- outcome ({wall_s:.2}s wall)");
    println!(
        "served {served}   refused 429/{refused_429} 503/{refused_503} shed/{sheds}   \
         client-cancels {client_cancels} (server cancelled {})   io-errors {io_errors}",
        st.cancelled
    );
    println!(
        "tokens {tokens_total} ({tok_s:.0} tok/s aggregate)   evictions {}   \
         pool refusals {}   batch fill {:.2}",
        st.evicted,
        st.pool_refusals,
        st.batch_fill()
    );
    println!(
        "ttft p50 {:.1}ms p99 {:.1}ms   per-token p50 {:.2}ms p99 {:.2}ms",
        percentile(&ttfts, 0.50),
        percentile(&ttfts, 0.99),
        percentile(&gaps, 0.50),
        percentile(&gaps, 0.99),
    );
    for (name, tokens) in &by_tenant {
        println!("  {name}: {tokens} served tokens");
    }
    let share_ratio = if by_tenant.len() >= 2 && by_tenant.iter().all(|(_, t)| *t > 0) {
        // tenants sort lexically; report first/last (a:3,b:1 -> ~3)
        by_tenant.first().unwrap().1 as f64 / by_tenant.last().unwrap().1 as f64
    } else {
        0.0
    };
    if share_ratio > 0.0 {
        println!("tenant share ratio (first/last, weights want it ~weight ratio): {share_ratio:.2}");
    }

    // ---- the npusim twin: price this tick shape on the modeled NPU
    let ncfg = NpuConfig::default();
    let plan = ServeTickPlan::build(
        Method::Muxq,
        fp.cfg.n_layer,
        fp.cfg.d_model,
        8,
        8,
        8,
        p.get_usize("max-live")?,
        tenants.len(),
    );
    let sim_cap = plan.tok_per_s(&ncfg);
    let sim_util = plan.utilization(&ncfg, tok_s);
    let sim_sched = plan.sched_overhead_fraction(&ncfg);
    println!(
        "\nnpusim serve tick: modeled capacity {sim_cap:.0} tok/s, predicted utilization \
         {:.1}% at the measured rate, DWRR overhead {:.4}% of the tick",
        sim_util * 100.0,
        sim_sched * 100.0
    );

    // sanity: the harness itself asserts the load actually served
    assert!(served > 0, "no client was served at all");
    assert!(tokens_total > 0, "no tokens streamed");
    for o in outcomes.iter().filter(|o| o.finish == "length") {
        assert_eq!(
            o.tokens, steps,
            "a finish=length stream carried {} tokens, wanted {steps}",
            o.tokens
        );
    }

    if !p.get("json").is_empty() {
        let json = format!(
            "{{\n  \"bench\": \"stress_serve\",\n  \"bootstrap\": false,\n  \
             \"conns\": {conns},\n  \"rounds\": {rounds},\n  \"steps\": {steps},\n  \
             \"served\": {served},\n  \"refused_429\": {refused_429},\n  \
             \"refused_503\": {refused_503},\n  \"sheds\": {sheds},\n  \
             \"io_errors\": {io_errors},\n  \"client_cancels\": {client_cancels},\n  \
             \"server_cancelled\": {},\n  \"evictions\": {},\n  \
             \"tokens_total\": {tokens_total},\n  \"tok_s\": {tok_s:.1},\n  \
             \"ttft_p50_ms\": {:.2},\n  \"ttft_p99_ms\": {:.2},\n  \
             \"per_token_p50_ms\": {:.3},\n  \"per_token_p99_ms\": {:.3},\n  \
             \"tenant_share_ratio\": {share_ratio:.3},\n  \
             \"sim_npu_util\": {sim_util:.4},\n  \"sim_sched_overhead\": {sim_sched:.6}\n}}\n",
            st.cancelled,
            st.evicted,
            percentile(&ttfts, 0.50),
            percentile(&ttfts, 0.99),
            percentile(&gaps, 0.50),
            percentile(&gaps, 0.99),
        );
        std::fs::write(p.get("json"), &json)?;
        println!("wrote {}", p.get("json"));
    }

    srv.shutdown();
    Ok(())
}
