//! Print the GEMM kernel the runtime dispatcher resolves on THIS host —
//! the one-line provenance every CI log carries (`rust/scripts/
//! ci_check.sh` runs this right after the build), so a green matrix leg
//! states which of the dispatcher's branches it actually exercised.
//!
//!     cargo run --release --example kernel_dispatch
//!     MUXQ_FORCE_KERNEL=scalar cargo run --release --example kernel_dispatch

use muxq::npusim::NpuConfig;
use muxq::quant::packed::TileConfig;
use muxq::quant::simd;

fn main() {
    let caps = simd::host_caps();
    let dispatch = simd::dispatch();
    println!(
        "host caps: avx2={} neon={} neon_dot={}",
        caps.avx2, caps.neon, caps.neon_dot
    );
    println!(
        "forced:    MUXQ_FORCE_KERNEL={}",
        std::env::var("MUXQ_FORCE_KERNEL").unwrap_or_else(|_| "(unset)".to_string())
    );
    println!("dispatch:  {}", dispatch.name());
    // the per-arch tile table this dispatch selects (deep-K column is
    // where the SIMD and scalar tables disagree)
    println!(
        "tiles:     nr(768,768)={} nr(deep-K)={} mr(512)={} gemv_max_m={}",
        TileConfig::nr_for(768, 768),
        TileConfig::nr_for(1 << 20, 768),
        TileConfig::mr_for(512),
        TileConfig::gemv_max_m()
    );
    // the npusim datapath this kernel generation is priced at
    println!(
        "npusim:    int_macs_per_cycle={}",
        NpuConfig::for_kernel(dispatch).int_macs_per_cycle()
    );
}
