//! SmoothQuant composability (paper contribution #2): MUXQ combined with
//! the SmoothQuant difficulty migration — both at the matrix level (rust
//! engine) and at the model level (AOT `-sq` variants).
//!
//!     cargo run --release --example smoothquant_combo

use anyhow::Result;
use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::harness::{eval_ppl, eval_windows, table_windows};
use muxq::quant::muxq::{fq_muxq, MuxqParams};
use muxq::quant::smooth::{migrate, smooth_scales};
use muxq::quant::{fq_naive, Granularity, MatF32};

fn main() -> Result<()> {
    // ---- matrix level
    let mut rng = muxq::data::prng::SplitMix64::new(11);
    let mut x = MatF32::from_vec(
        256,
        96,
        (0..256 * 96).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
    )?;
    for r in 0..x.rows {
        *x.at_mut(r, 10) *= 40.0;
        *x.at_mut(r, 70) *= 18.0;
    }
    let w = MatF32::from_vec(
        96,
        64,
        (0..96 * 64).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
    )?;
    let s = smooth_scales(&x.absmax_cols(), &w, 0.5);
    let (xs, _ws) = migrate(&x, &w, &s);
    let qmax = 31.0; // 6-bit activations, where composition matters
    let p = MuxqParams::default();
    let rel = |e: f32, m: &MatF32| e / m.absmax();
    println!("matrix-level relative MAE at 6-bit per-tensor activations:\n");
    println!("  naive                : {:.6}", rel(fq_naive(&x, qmax, Granularity::PerTensor).mean_abs_diff(&x), &x));
    println!("  smoothquant          : {:.6}", rel(fq_naive(&xs, qmax, Granularity::PerTensor).mean_abs_diff(&xs), &xs));
    println!("  muxq                 : {:.6}", rel(fq_muxq(&x, qmax, Granularity::PerTensor, &p).mean_abs_diff(&x), &x));
    println!("  smoothquant + muxq   : {:.6}", rel(fq_muxq(&xs, qmax, Granularity::PerTensor, &p).mean_abs_diff(&xs), &xs));

    // ---- model level (AOT -sq variants bake the calibrated migration)
    match VariantRegistry::open_default() {
        Ok(registry) => {
            let windows = eval_windows(table_windows())?;
            println!("\nmodel-level perplexity, sim-small per-tensor:");
            println!("{:<24} {:>10} {:>10}", "variant", "IA=8", "IA=6");
            for (label, tag) in [
                ("naive", "naive-pt"),
                ("naive + smoothquant", "naive-pt-sq"),
                ("muxq", "muxq-pt"),
                ("muxq + smoothquant", "muxq-pt-sq"),
                ("fp16", "fp16-pt"),
            ] {
                let key = VariantKey::eval("sim-small", tag);
                if registry.meta(&key).is_none() {
                    continue;
                }
                let p8 = eval_ppl(&registry, &key, 8.0, 8.0, &windows)?;
                let p6 = eval_ppl(&registry, &key, 6.0, 8.0, &windows)?;
                println!("{label:<24} {p8:>10.4} {p6:>10.4}");
            }
            println!(
                "\nThe paper's claim: MUXQ composes with difficulty-migration methods —\n\
                 the combination should be at least as good as either alone at low bits."
            );
        }
        Err(e) => println!("\n(model-level comparison skipped: {e})"),
    }
    Ok(())
}
