//! SmoothQuant composability (paper contribution #2): MUXQ combined with
//! the SmoothQuant difficulty migration — both at the matrix level (rust
//! engine) and at the model level (AOT `-sq` variants).
//!
//!     cargo run --release --example smoothquant_combo

use anyhow::Result;
use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::harness::{eval_ppl, eval_windows, table_windows};
use muxq::quant::muxq::{fq_muxq, MuxqParams};
use muxq::quant::smooth::{migrate, smooth_scales};
use muxq::quant::{fq_naive, EngineSpec, Granularity, MatF32, QuantLinear};

fn main() -> Result<()> {
    // ---- matrix level
    let mut rng = muxq::data::prng::SplitMix64::new(11);
    let mut x = MatF32::from_vec(
        256,
        96,
        (0..256 * 96).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
    )?;
    for r in 0..x.rows {
        *x.at_mut(r, 10) *= 40.0;
        *x.at_mut(r, 70) *= 18.0;
    }
    let w = MatF32::from_vec(
        96,
        64,
        (0..96 * 64).map(|_| (rng.next_f64() as f32 - 0.5) * 2.0).collect(),
    )?;
    let s = smooth_scales(&x.absmax_cols(), &w, 0.5);
    let (xs, _ws) = migrate(&x, &w, &s);
    let qmax = 31.0; // 6-bit activations, where composition matters
    let p = MuxqParams::default();
    let rel = |e: f32, m: &MatF32| e / m.absmax();
    println!("matrix-level relative MAE at 6-bit per-tensor activations:\n");
    println!(
        "  naive                : {:.6}",
        rel(fq_naive(&x, qmax, Granularity::PerTensor).mean_abs_diff(&x), &x)
    );
    println!(
        "  smoothquant          : {:.6}",
        rel(fq_naive(&xs, qmax, Granularity::PerTensor).mean_abs_diff(&xs), &xs)
    );
    println!(
        "  muxq                 : {:.6}",
        rel(fq_muxq(&x, qmax, Granularity::PerTensor, &p).mean_abs_diff(&x), &x)
    );
    println!(
        "  smoothquant + muxq   : {:.6}",
        rel(fq_muxq(&xs, qmax, Granularity::PerTensor, &p).mean_abs_diff(&xs), &xs)
    );

    // ---- deployed operator level: the same composition through the
    // QuantLinear API — migration folded in at pack time, projections on
    // the packed INT engine (what the generation server actually runs)
    let exact = muxq::quant::gemm::matmul_f32(&x, &w);
    let bias = vec![0.0f32; w.cols];
    let amax = x.absmax_cols();
    let plain = EngineSpec::muxq().with_bits(6, 8).pack(&w, &bias).forward(&x);
    let combo = EngineSpec::muxq()
        .with_bits(6, 8)
        .with_smooth(0.5)
        .pack_calibrated(&w, &bias, Some(&amax))
        .forward(&x);
    println!("\ndeployed-operator MAE vs exact FP (6-bit activations, packed INT engine):");
    println!(
        "  {:<21}: {:.6}",
        EngineSpec::muxq().with_bits(6, 8).tag(),
        plain.mean_abs_diff(&exact)
    );
    println!(
        "  {:<21}: {:.6}",
        EngineSpec::muxq().with_bits(6, 8).with_smooth(0.5).tag(),
        combo.mean_abs_diff(&exact)
    );

    // ---- model level (AOT -sq variants bake the calibrated migration)
    match VariantRegistry::open_default() {
        Ok(registry) => {
            let windows = eval_windows(table_windows())?;
            println!("\nmodel-level perplexity, sim-small per-tensor:");
            println!("{:<24} {:>10} {:>10}", "variant", "IA=8", "IA=6");
            // smoothing is spelled on the spec (`with_smooth` -> the
            // canonical `-sq` tag), not as a hand-written string
            let pt = |s: EngineSpec| {
                s.with_granularity(Granularity::PerTensor, Granularity::PerTensor)
            };
            for (label, spec) in [
                ("naive", pt(EngineSpec::naive())),
                ("naive + smoothquant", pt(EngineSpec::naive()).with_smooth(0.5)),
                ("muxq", pt(EngineSpec::muxq())),
                ("muxq + smoothquant", pt(EngineSpec::muxq()).with_smooth(0.5)),
                ("fp16", pt(EngineSpec::fp16())),
            ] {
                let key = VariantKey::eval("sim-small", &spec.tag());
                if registry.meta(&key).is_none() {
                    continue;
                }
                let p8 = eval_ppl(&registry, &key, 8.0, 8.0, &windows)?;
                let p6 = eval_ppl(&registry, &key, 6.0, 8.0, &windows)?;
                println!("{label:<24} {p8:>10.4} {p6:>10.4}");
            }
            println!(
                "\nThe paper's claim: MUXQ composes with difficulty-migration methods —\n\
                 the combination should be at least as good as either alone at low bits."
            );
        }
        Err(e) => println!("\n(model-level comparison skipped: {e})"),
    }
    Ok(())
}
