//! Figure 3 regenerator: outliers shrink the effective quantization grid.
//!
//! The paper's Fig. 3 illustrates how one outlier inflates the abs-max
//! scale so all normal values collapse onto few integer levels. We
//! measure exactly that: level occupancy and error on synthetic matrices
//! with controlled outlier magnitude, for naive vs MUXQ vs LLM.int8().
//!
//!     cargo run --release --example fig3_quant_error

use anyhow::Result;
use muxq::data::prng::SplitMix64;
use muxq::harness::bar;
use muxq::quant::{fq_naive, Granularity, MatF32, Method, QuantSpec};

fn outlier_matrix(scale: f32, seed: u64) -> MatF32 {
    let mut rng = SplitMix64::new(seed);
    let mut m = MatF32::from_vec(
        256,
        64,
        (0..256 * 64).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect(),
    )
    .unwrap();
    for r in 0..m.rows {
        *m.at_mut(r, 7) *= scale;
        *m.at_mut(r, 40) *= scale;
    }
    m
}

fn occupied_levels(x: &MatF32, qmax: f32) -> usize {
    let s = x.absmax().max(1e-8) / qmax;
    let mut seen = std::collections::BTreeSet::new();
    for v in &x.data {
        seen.insert((v / s).round() as i32);
    }
    seen.len()
}

fn main() -> Result<()> {
    println!("Fig. 3: effect of outlier magnitude on per-tensor INT8 quantization\n");
    println!(
        "{:>12} {:>8} {:>12} {:>12} {:>12}",
        "outlier x", "levels", "naive MAE", "MUXQ MAE", "llm.int8 MAE"
    );
    let qmax = 127.0;
    for scale in [1.0f32, 4.0, 8.0, 16.0, 32.0, 64.0] {
        let x = outlier_matrix(scale, 42);
        let levels = occupied_levels(&x, qmax);
        let e_naive = fq_naive(&x, qmax, Granularity::PerTensor).mean_abs_diff(&x);
        let e_muxq = QuantSpec::new(Method::Muxq, "per-tensor", 8, 8)?.fq_act(&x).mean_abs_diff(&x);
        let e_int8 =
            QuantSpec::new(Method::LlmInt8, "per-tensor", 8, 8)?.fq_act(&x).mean_abs_diff(&x);
        println!(
            "{:>12.1} {:>8} {:>12.5} {:>12.5} {:>12.5}",
            scale, levels, e_naive, e_muxq, e_int8
        );
    }

    // density sketch: value distribution vs the INT8 grid, with and
    // without an outlier (the figure's visual)
    println!("\nValue-distribution densification (share of values per |level| band):");
    for (label, scale) in [("no outliers", 1.0f32), ("outlier x32", 32.0)] {
        let x = outlier_matrix(scale, 7);
        let s = x.absmax() / qmax;
        let mut bands = [0usize; 8];
        for v in &x.data {
            let lvl = (v / s).abs().round() as usize;
            bands[(lvl * 8 / 128).min(7)] += 1;
        }
        let max = *bands.iter().max().unwrap() as f32;
        println!("  {label}:");
        for (i, b) in bands.iter().enumerate() {
            println!(
                "    levels {:>3}-{:>3} |{:<40}| {}",
                i * 16,
                i * 16 + 15,
                bar(*b as f32, max, 40),
                b
            );
        }
    }
    println!("\nWith a large outlier, nearly all mass collapses into the lowest level");
    println!("band (the paper's Fig. 3); MUXQ restores the spread by shifting outlier");
    println!("channels down by 2^exp before scaling.");
    Ok(())
}
