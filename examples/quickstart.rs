//! Quickstart: load the artifacts, score one text under FP16 vs MUXQ
//! INT8, and print perplexities — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use anyhow::Result;
use muxq::coordinator::{VariantKey, VariantRegistry};
use muxq::data::bpe::Bpe;
use muxq::data::eval_set::EvalSet;
use muxq::harness::eval_ppl;
use muxq::quant::{EngineSpec, Granularity};

fn main() -> Result<()> {
    let artifacts = muxq::artifacts_dir();

    // 1. the tokenizer trained at build time
    let bpe = Bpe::load(artifacts.join("corpus").join("tokenizer.bpe"))?;
    println!("tokenizer: {} tokens", bpe.vocab_size());
    let sample = "The quick brown fox jumps over the lazy dog.";
    let ids = bpe.encode(sample);
    println!("encode({sample:?}) -> {} tokens, roundtrip ok: {}",
        ids.len(), bpe.decode(&ids) == sample);

    // 2. the compiled model variants (PJRT executables from jax+pallas)
    let registry = VariantRegistry::open_default()?;
    println!("\navailable variants: {}", registry.keys().len());

    // 3. score validation windows under three quantization schemes
    let eval = EvalSet::load(&artifacts, "valid")?;
    let windows = eval.windows(128, 8);
    println!("\nperplexity on {} validation windows (sim-small):", windows.len());
    // canonical variant tags come from EngineSpec — one spelling,
    // shared with the manifest and the deployed pipeline
    let pt = |s: EngineSpec| s.with_granularity(Granularity::PerTensor, Granularity::PerTensor);
    for (label, spec, ia, w) in [
        ("FP16 reference     ", pt(EngineSpec::fp16()), 8.0, 8.0),
        ("naive INT8/tensor  ", pt(EngineSpec::naive()), 8.0, 8.0),
        ("MUXQ  INT8/tensor  ", pt(EngineSpec::muxq()), 8.0, 8.0),
        ("MUXQ  INT6 acts    ", pt(EngineSpec::muxq()), 6.0, 8.0),
    ] {
        let key = VariantKey::eval("sim-small", &spec.tag());
        let ppl = eval_ppl(&registry, &key, ia, w, &windows)?;
        println!("  {label} ppl = {ppl:.4}");
    }
    println!("\nMUXQ holds perplexity near FP16 where naive per-tensor INT8 degrades.");
    Ok(())
}
