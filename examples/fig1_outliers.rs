//! Figure 1 regenerator: activation outliers concentrate in a few
//! channels (left); MUXQ's decomposition reduces those channels'
//! magnitudes (right).
//!
//! Data source: the calibration capture (`artifacts/calib/<model>.bin`,
//! per-channel abs-max at each projection site) plus the rust MUXQ
//! decomposition applied to live activations from the native GPT-2.
//!
//!     cargo run --release --example fig1_outliers

use anyhow::Result;
use muxq::data::eval_set::EvalSet;
use muxq::data::tensors::TensorFile;
use muxq::gpt2::Gpt2Model;
use muxq::harness::bar;
use muxq::quant::muxq::{decompose, outlier_mask, MuxqParams};

const THETA: f32 = 6.0;

fn main() -> Result<()> {
    let artifacts = muxq::artifacts_dir();
    let model = "sim-small";
    let calib = TensorFile::read(artifacts.join("calib").join(format!("{model}.bin")))?;

    // ---- left panel: calibration abs-max profile at the c_fc input of
    // block 0 (the paper's canonical outlier site)
    let site = "absmax/block00/c_fc";
    let absmax = calib.get(site)?.as_f32()?;
    let max = absmax.iter().cloned().fold(0.0f32, f32::max);
    let n_out = absmax.iter().filter(|&&v| v > THETA).count();
    println!("Fig. 1 (left): per-channel |x|max at {model} {site}");
    println!(
        "channels: {}   outlier channels (theta={THETA}): {n_out}   max: {max:.1}\n",
        absmax.len()
    );
    print_profile(&absmax, max);

    // ---- right panel: the same activations after MUXQ decomposition
    // (Body path), computed live through the native model
    let gpt2 = Gpt2Model::load_from_artifacts(model)?;
    let eval = EvalSet::load(&artifacts, "valid")?;
    let tokens = eval.windows_u32(128, 2);
    let mut cap = muxq::gpt2::SiteCapture::new();
    gpt2.forward(&tokens, None, Some(&mut cap))?;
    let live = &cap[&(0, "c_fc")];

    // apply the decomposition to the abs-max profile: Body halves the
    // outlier channels by 2^exp
    let p = MuxqParams::default();
    let as_mat = muxq::quant::MatF32::from_vec(1, live.len(), live.clone())?;
    let mask = outlier_mask(&as_mat, p.theta);
    let (body, _aux) = decompose(&as_mat, &mask, &p);
    let body_max = body.data.iter().cloned().fold(0.0f32, f32::max);
    println!("\nFig. 1 (right): after MUXQ (Body path, exp_factor={})", p.exp_factor);
    println!("max |x| {:.1} -> {:.1}  (outlier channels shifted by 2^{})\n",
        live.iter().cloned().fold(0.0f32, f32::max), body_max, p.exp_factor);
    print_profile(&body.data, max);

    println!("\nOutlier magnitude is redistributed into the Aux path; the Body matrix");
    println!("now quantizes at per-tensor INT8 without the outlier-driven scale blowup.");
    Ok(())
}

/// ASCII profile: one row per channel bucket (top-16 channels by |x|max,
/// plus a tail summary).
fn print_profile(vals: &[f32], scale_max: f32) {
    let mut idx: Vec<usize> = (0..vals.len()).collect();
    idx.sort_by(|&a, &b| vals[b].total_cmp(&vals[a]));
    for &i in idx.iter().take(16) {
        let v = vals[i];
        let marker = if v > THETA { " <-- outlier" } else { "" };
        println!("  ch {i:>4} {v:>8.2} |{:<40}|{marker}", bar(v, scale_max, 40));
    }
    let rest: Vec<f32> = idx.iter().skip(16).map(|&i| vals[i]).collect();
    if !rest.is_empty() {
        let mean = rest.iter().sum::<f32>() / rest.len() as f32;
        println!("  ... {} more channels, mean |x|max {mean:.2}", rest.len());
    }
}
