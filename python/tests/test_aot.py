"""AOT export pipeline tests (tiny configs — the full build is exercised
by `make artifacts`)."""

import json
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.aot import build_eval_fn, sorted_weight_names, to_hlo_text
from compile.config import ModelConfig, QuantConfig
from compile.iohelpers import (params_to_tensors, read_tensors,
                               tensors_to_params, write_tensors)
from compile.model import init_params, nll_sums

CFG = ModelConfig("t", n_layer=1, d_model=32, n_head=2, n_ctx=16, vocab_size=64)


@pytest.fixture(scope="module")
def flat():
    return params_to_tensors(init_params(CFG, seed=3))


def test_tensor_container_roundtrip(tmp_path, flat):
    p = tmp_path / "w.bin"
    write_tensors(p, flat)
    back = read_tensors(p)
    assert set(back) == set(flat)
    for k in flat:
        np.testing.assert_array_equal(back[k], np.asarray(flat[k]))


def test_tensors_to_params_inverse(flat):
    params = tensors_to_params(flat, CFG.n_layer)
    flat2 = params_to_tensors(params)
    assert set(flat2) == set(flat)


def test_sorted_names_stable(flat):
    names = sorted_weight_names(flat)
    assert names == sorted(names)
    assert "wte" in names


@pytest.mark.parametrize("method,gran", [
    ("fp16", "per-tensor"),
    ("naive", "per-tensor"),
    ("muxq", "per-vector"),
    ("llmint8", "per-tensor"),
])
def test_export_hlo_text(flat, method, gran):
    """Every variant lowers to parseable HLO text with the agreed input
    signature (weights sorted, tokens, ia_bits, w_bits)."""
    names = sorted_weight_names(flat)
    specs = [jax.ShapeDtypeStruct(flat[n].shape, jnp.float32) for n in names]
    tok = jax.ShapeDtypeStruct((2, CFG.n_ctx), jnp.int32)
    bit = jax.ShapeDtypeStruct((), jnp.float32)
    fn = build_eval_fn(CFG, QuantConfig(method, gran), names, "eval")
    lowered = jax.jit(fn).lower(*specs, tok, bit, bit)
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert text.count("parameter") >= len(names) + 3


def test_exported_fn_matches_direct_eval(flat):
    """The closed-over export fn computes the same nll as calling the
    model directly — guards against weight-ordering bugs."""
    names = sorted_weight_names(flat)
    fn = build_eval_fn(CFG, QuantConfig("muxq", "per-tensor"), names, "eval")
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 64, size=(2, 16)).astype(np.int32))
    args = [jnp.asarray(flat[n]) for n in names] + [toks,
            jnp.asarray(8.0, jnp.float32), jnp.asarray(8.0, jnp.float32)]
    s, c = fn(*args)  # per-sequence arrays [B]
    assert s.shape == (2,) and c.shape == (2,)
    params = tensors_to_params(flat, CFG.n_layer)
    s2, c2 = nll_sums(params, toks, CFG, qcfg=QuantConfig("muxq", "per-tensor"),
                      ia_bits=8.0, w_bits=8.0)
    assert float(jnp.sum(c)) == float(c2)
    np.testing.assert_allclose(float(jnp.sum(s)), float(s2), rtol=1e-5)


def test_logits_kind_shape(flat):
    names = sorted_weight_names(flat)
    fn = build_eval_fn(CFG, QuantConfig("fp16", "per-tensor"), names, "logits")
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, 64, size=(2, 16)).astype(np.int32))
    args = [jnp.asarray(flat[n]) for n in names] + [toks,
            jnp.asarray(8.0, jnp.float32), jnp.asarray(8.0, jnp.float32)]
    (logits,) = fn(*args)
    assert logits.shape == (2, 16, 64)


def test_manifest_written_by_full_build():
    """If the background artifact build has completed, validate manifest
    integrity (skipped otherwise)."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[2] / "artifacts"
    mf = root / "manifest.json"
    if not mf.exists():
        pytest.skip("full artifacts not built yet")
    entries = json.loads(mf.read_text())
    assert len(entries) >= 20
    for e in entries:
        assert (root / "hlo" / e["file"]).exists(), e["file"]
        assert (root / e["weights"]).exists()
