"""L2 model tests: shapes, causality, outlier injection, eval graph."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.config import ModelConfig, QuantConfig
from compile.model import (forward, init_params, inject_outliers, lm_loss,
                           nll_sums)

CFG = ModelConfig("t", n_layer=2, d_model=32, n_head=2, n_ctx=16, vocab_size=64)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def tokens():
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, CFG.vocab_size, size=(2, CFG.n_ctx)).astype(np.int32))


def test_forward_shape(params, tokens):
    logits = forward(params, tokens, CFG)
    assert logits.shape == (2, CFG.n_ctx, CFG.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_causality(params, tokens):
    """Changing a future token must not affect earlier logits."""
    logits_a = forward(params, tokens, CFG)
    toks_b = tokens.at[:, -1].set((tokens[:, -1] + 1) % CFG.vocab_size)
    logits_b = forward(params, toks_b, CFG)
    np.testing.assert_allclose(np.asarray(logits_a[:, :-1]),
                               np.asarray(logits_b[:, :-1]), rtol=1e-6, atol=1e-6)


def test_param_count_formula(params):
    import jax
    n = sum(int(np.prod(t.shape)) for t in jax.tree_util.tree_leaves(params))
    assert n == CFG.param_count()


def test_injection_function_preserving(params, tokens):
    inj = inject_outliers(params, CFG, channels_per_block=3, alpha=10.0)
    a = forward(params, tokens, CFG)
    b = forward(inj, tokens, CFG)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


def test_injection_creates_outliers(params, tokens):
    """Post-LN activations feeding c_attn/c_fc must carry channels above
    the theta=6 criterion after injection."""
    inj = inject_outliers(params, CFG, channels_per_block=3, alpha=12.0)
    cap_before, cap_after = {}, {}
    forward(params, tokens, CFG, capture=cap_before)
    forward(inj, tokens, CFG, capture=cap_after)
    before = float(np.asarray(cap_before[(0, "c_fc")]).max())
    after = float(np.asarray(cap_after[(0, "c_fc")]).max())
    assert after > before * 5
    n_outlier = int((np.asarray(cap_after[(0, "c_fc")]) > 6.0).sum())
    assert n_outlier >= 1


def test_injection_degrades_naive_more_than_muxq(params, tokens):
    """With injected outliers and low activation precision, MUXQ's logits
    track the FP forward more closely than naive quantization (the
    mechanism behind Table 1)."""
    inj = inject_outliers(params, CFG, channels_per_block=3, alpha=16.0)
    fp = np.asarray(forward(inj, tokens, CFG))
    err = {}
    for method in ("naive", "muxq", "llmint8"):
        lg = forward(inj, tokens, CFG, qcfg=QuantConfig(method, "per-tensor"),
                     ia_bits=6.0, w_bits=8.0)
        err[method] = float(np.mean(np.abs(np.asarray(lg) - fp)))
    assert err["muxq"] < err["naive"]
    assert err["llmint8"] <= err["muxq"] * 1.5


def test_quantized_forward_all_variants(params, tokens):
    for method in ("fp16", "naive", "muxq", "llmint8"):
        for gran in ("per-vector", "per-tensor"):
            s, c = nll_sums(params, tokens, CFG,
                            qcfg=QuantConfig(method, gran),
                            ia_bits=8.0, w_bits=8.0)
            assert np.isfinite(float(s))
            assert float(c) == 2 * (CFG.n_ctx - 1)


def test_fp16_variant_equals_unquantized(params, tokens):
    s0, _ = nll_sums(params, tokens, CFG)
    s1, _ = nll_sums(params, tokens, CFG, qcfg=QuantConfig("fp16", "per-tensor"),
                     ia_bits=8.0, w_bits=8.0)
    assert abs(float(s0) - float(s1)) < 1e-4


def test_loss_decreases_with_training_signal():
    """Single gradient step on a repeating batch lowers loss (training
    plumbing sanity)."""
    import jax
    from compile.train import adamw_init, adamw_update
    params = init_params(CFG, seed=1)
    rng = np.random.default_rng(1)
    batch = jnp.asarray(rng.integers(0, 64, size=(4, 16)).astype(np.int32))
    loss0, grads = jax.value_and_grad(lm_loss)(params, batch, CFG)
    opt = adamw_init(params)
    for _ in range(5):
        loss, grads = jax.value_and_grad(lm_loss)(params, batch, CFG)
        params, opt = adamw_update(params, grads, opt, 1e-2)
    loss1 = lm_loss(params, batch, CFG)
    assert float(loss1) < float(loss0)
