"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

hypothesis sweeps shapes and value distributions (including adversarial
outlier structure); assertions are exact where the math is exact.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    absmax_rows_pallas,
    fake_quant_pallas,
    muxq_decompose_pallas,
    quant_matmul_pallas,
    ref,
)
from compile.kernels.tiling import pick_block, vmem_bytes_quant_matmul

DIMS = st.sampled_from([1, 2, 3, 4, 8, 12, 16, 24, 32, 48, 64, 96, 128, 160, 256])
BITS = st.sampled_from([4.0, 5.0, 6.0, 7.0, 8.0])
SEED = st.integers(0, 2**31 - 1)


def rand(shape, seed, outlier_cols=0, outlier_scale=20.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    if outlier_cols:
        cols = rng.choice(shape[1], size=min(outlier_cols, shape[1]), replace=False)
        x[:, cols] *= outlier_scale
    return x


# ------------------------------------------------------------- pick_block
@given(st.integers(1, 4096))
def test_pick_block_divides(dim):
    b = pick_block(dim)
    assert dim % b == 0
    assert b <= 512
    assert b & (b - 1) == 0  # power of two


def test_vmem_estimate_within_budget():
    # the default tiling must fit a 16 MiB VMEM with double-buffering
    assert vmem_bytes_quant_matmul(128, 1024, 128) < 16 * 2**20
    assert vmem_bytes_quant_matmul(512, 1024, 512) < 16 * 2**20


# ---------------------------------------------------------------- absmax
@settings(deadline=None, max_examples=25)
@given(DIMS, DIMS, SEED)
def test_absmax_rows(m, n, seed):
    x = jnp.asarray(rand((m, n), seed))
    got = absmax_rows_pallas(x)
    want = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------------ fake quant
@settings(deadline=None, max_examples=25)
@given(DIMS, DIMS, BITS, SEED, st.sampled_from(["row", "col", "tensor"]))
def test_fake_quant_matches_ref(m, n, bits, seed, gran):
    x = jnp.asarray(rand((m, n), seed, outlier_cols=1))
    q = float(2 ** (bits - 1) - 1)
    axis = {"row": 1, "col": 0, "tensor": None}[gran]
    s = ref.absmax_scale(x, q, axis=axis)
    if axis is None:
        s = s.reshape(1, 1)
    got = fake_quant_pallas(x, s, q)
    want = ref.fake_quant(x, s, q)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fake_quant_idempotent():
    x = jnp.asarray(rand((32, 64), 3))
    q = 127.0
    s = ref.absmax_scale(x, q).reshape(1, 1)
    once = fake_quant_pallas(x, s, q)
    twice = fake_quant_pallas(once, s, q)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice), rtol=1e-6)


def test_fake_quant_levels_bounded():
    x = jnp.asarray(rand((16, 16), 9) * 100)
    for bits in (4.0, 8.0):
        q = float(2 ** (bits - 1) - 1)
        s = ref.absmax_scale(x, q).reshape(1, 1)
        y = np.asarray(fake_quant_pallas(x, s, q))
        levels = np.unique(np.round(y / np.asarray(s)))
        assert levels.size <= 2 * q + 1
        assert np.abs(levels).max() <= q


# ----------------------------------------------------------------- muxq
@settings(deadline=None, max_examples=25)
@given(DIMS, DIMS, SEED, st.sampled_from([1, 2, 3, 4]))
def test_muxq_decompose_matches_ref_and_reconstructs(m, n, seed, exp):
    x = jnp.asarray(rand((m, n), seed, outlier_cols=2))
    mask = ref.outlier_mask(x, 6.0)
    body, aux = muxq_decompose_pallas(x, mask, float(exp))
    body_r, aux_r = ref.muxq_decompose(x, mask, float(exp))
    np.testing.assert_array_equal(np.asarray(body), np.asarray(body_r))
    np.testing.assert_array_equal(np.asarray(aux), np.asarray(aux_r))
    # exact FP identity (paper eq. 6)
    rec = ref.muxq_reconstruct(body, aux, float(exp))
    np.testing.assert_allclose(np.asarray(rec), np.asarray(x), rtol=1e-6, atol=1e-6)


def test_muxq_reduces_outlier_magnitude():
    x = jnp.asarray(rand((64, 32), 0, outlier_cols=3, outlier_scale=30.0))
    mask = ref.outlier_mask(x, 6.0)
    assert np.asarray(mask).sum() >= 3
    body, _ = muxq_decompose_pallas(x, mask, 2.0)
    body_max = np.abs(np.asarray(body)).max()
    x_max = np.abs(np.asarray(x)).max()
    assert body_max <= x_max / 4 + 1e-6


def test_muxq_no_outliers_is_identity():
    x = jnp.asarray(rand((16, 16), 5) * 0.1)  # everything far below theta
    mask = ref.outlier_mask(x, 6.0)
    assert np.asarray(mask).sum() == 0
    body, aux = muxq_decompose_pallas(x, mask, 2.0)
    np.testing.assert_array_equal(np.asarray(body), np.asarray(x))
    assert np.abs(np.asarray(aux)).max() == 0.0


# --------------------------------------------------------------- qmatmul
@settings(deadline=None, max_examples=20)
@given(DIMS, DIMS, DIMS, BITS, SEED, st.booleans())
def test_quant_matmul_matches_ref(m, k, n, bits, seed, per_tensor):
    x = jnp.asarray(rand((m, k), seed, outlier_cols=1))
    w = jnp.asarray(rand((k, n), seed + 1))
    q = float(2 ** (bits - 1) - 1)
    if per_tensor:
        sx = ref.absmax_scale(x, q).reshape(1, 1)
        sw = ref.absmax_scale(w, q).reshape(1, 1)
    else:
        sx = ref.absmax_scale(x, q, axis=1)
        sw = ref.absmax_scale(w, q, axis=0)
    got = quant_matmul_pallas(x, w, sx, sw, q)
    want = ref.quant_matmul(x, w, sx, sw, q, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_quant_matmul_equals_fakequant_matmul():
    """quantize->matmul->dequant == fakequant(x) @ fakequant(w) (the scales
    factor out of the integer matmul) — the identity that makes the
    paper's fake-quant evaluation representative of the INT pipeline."""
    x = jnp.asarray(rand((64, 96), 11, outlier_cols=2))
    w = jnp.asarray(rand((96, 32), 12))
    q = 127.0
    sx = ref.absmax_scale(x, q, axis=1)
    sw = ref.absmax_scale(w, q, axis=0)
    got = quant_matmul_pallas(x, w, sx, sw, q)
    fq = ref.fake_quant(x, sx, q) @ ref.fake_quant(w, sw, q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(fq), rtol=1e-5, atol=1e-4)


def test_quant_error_shrinks_with_bits():
    x = jnp.asarray(rand((64, 64), 21))
    w = jnp.asarray(rand((64, 64), 22))
    exact = np.asarray(x) @ np.asarray(w)
    errs = []
    for bits in (4.0, 6.0, 8.0):
        q = float(2 ** (bits - 1) - 1)
        sx = ref.absmax_scale(x, q, axis=1)
        sw = ref.absmax_scale(w, q, axis=0)
        y = np.asarray(quant_matmul_pallas(x, w, sx, sw, q))
        errs.append(np.abs(y - exact).mean())
    assert errs[0] > errs[1] > errs[2]


# ----------------------------------------------------------- muxq fused
@settings(deadline=None, max_examples=20)
@given(DIMS, DIMS, BITS, SEED, st.sampled_from([1, 2, 3]), st.booleans())
def test_muxq_fused_matches_four_pass_reference(m, n, bits, seed, exp, per_row):
    """The fused single-pass kernel (perf pass, §Perf L1) must equal the
    decompose -> fq -> fq -> reconstruct reference exactly."""
    from compile.kernels import muxq_fused_fq_pallas
    x = jnp.asarray(rand((m, n), seed, outlier_cols=2))
    q = float(2 ** (bits - 1) - 1)
    axis = 1 if per_row else None
    mask = ref.outlier_mask(x, 6.0)
    body, aux = ref.muxq_decompose(x, mask, float(exp))
    s_body = ref.absmax_scale(body, q, axis=axis)
    s_aux = ref.absmax_scale(aux, q, axis=axis)
    if axis is None:
        s_body = s_body.reshape(1, 1)
        s_aux = s_aux.reshape(1, 1)
    got = muxq_fused_fq_pallas(x, mask, s_body, s_aux, q, float(exp))
    want = ref.muxq_reconstruct(
        ref.fake_quant(body, s_body, q), ref.fake_quant(aux, s_aux, q), float(exp))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_muxq_fused_equals_fq_muxq_end_to_end():
    from compile.quant import quantize_act
    from compile.config import QuantConfig
    x = jnp.asarray(rand((64, 96), 33, outlier_cols=3, outlier_scale=25.0))
    got, _ = quantize_act(x, QuantConfig("muxq", "per-tensor"), 63.0)
    want = ref.fq_muxq(x, 63.0, None, 6.0, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)
