"""Corpus generator + BPE tokenizer tests, including the cross-language
golden pins (rust/src/data mirrors these exactly)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.bpe import BPETokenizer, split_words, train
from compile.corpus import CorpusConfig, CorpusGenerator, generate, make_word
from compile.prng import MASK64, SplitMix64, mix, zipf_index


# ------------------------------------------------------------------ prng
def test_splitmix_known_values():
    """Golden values pinned against the rust twin
    (rust/src/data/prng.rs test `splitmix_known_values`)."""
    r = SplitMix64(0)
    assert r.next_u64() == 0xE220A8397B1DCDAF
    assert r.next_u64() == 0x6E789E6AA1B965F4
    r2 = SplitMix64(42)
    assert r2.next_u64() == 0xBDD732262FEB6E95


def test_splitmix_f64_in_unit_interval():
    r = SplitMix64(7)
    for _ in range(1000):
        v = r.next_f64()
        assert 0.0 <= v < 1.0


@given(st.integers(0, MASK64), st.integers(1, 10**6))
def test_next_below_in_range(seed, n):
    r = SplitMix64(seed)
    assert 0 <= r.next_below(n) < n


def test_mix_deterministic():
    assert mix(1, 2, 3) == mix(1, 2, 3)
    assert mix(1, 2, 3) != mix(3, 2, 1)


def test_zipf_skewed():
    r = SplitMix64(1)
    counts = np.zeros(100)
    for _ in range(20000):
        counts[zipf_index(r, 100)] += 1
    assert counts[0] > counts[10] > counts[50]


# ---------------------------------------------------------------- corpus
def test_corpus_deterministic():
    a, _ = generate(CorpusConfig(articles=3))
    b, _ = generate(CorpusConfig(articles=3))
    assert a == b


def test_corpus_golden_prefix():
    """Pinned against rust/src/data/corpus.rs `corpus_golden_prefix`."""
    gen = CorpusGenerator(CorpusConfig(articles=1))
    text = gen.split("train", articles=1)
    # Stability contract: regenerate goldens on BOTH sides if this changes.
    assert text.startswith("= "), text[:40]
    assert len(text) > 200


def test_train_valid_disjoint_streams():
    t, v = generate(CorpusConfig(articles=4))
    assert t[:500] != v[:500]


def test_corpus_has_wikitext_structure():
    t, _ = generate(CorpusConfig(articles=3))
    assert t.count("= ") >= 3  # headings
    assert ". " in t or ".\n" in t


def test_make_word_pronounceable():
    for i in range(50):
        w = make_word(i, 1)
        assert 4 <= len(w) <= 12
        assert w.isalpha()


# ------------------------------------------------------------------- bpe
@pytest.fixture(scope="module")
def tok():
    text, _ = generate(CorpusConfig(articles=5))
    return train(text, n_merges=64), text


def test_bpe_roundtrip(tok):
    t, text = tok
    sample = text[:2000]
    assert t.decode(t.encode(sample)) == sample


@settings(deadline=None, max_examples=30)
@given(st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126), max_size=200))
def test_bpe_roundtrip_arbitrary_ascii(tok, s):
    t, _ = tok
    assert t.decode(t.encode(s)) == s


def test_bpe_compresses(tok):
    t, text = tok
    sample = text[:4000]
    ids = t.encode(sample)
    assert len(ids) < len(sample.encode())  # better than raw bytes


def test_bpe_vocab_size(tok):
    t, _ = tok
    assert t.vocab_size == 256 + len(t.merges)
    assert t.vocab_size <= 512


def test_bpe_serialization_roundtrip(tok):
    t, text = tok
    t2 = BPETokenizer.load(t.dump())
    assert t2.merges == t.merges
    assert t2.encode(text[:500]) == t.encode(text[:500])


def test_split_words_preserves_bytes():
    s = "hello  world\n= Heading =\n\ntail "
    assert b"".join(split_words(s)) == s.encode()


def test_byte_fallback():
    """Any byte sequence stays encodable (token ids 0..255 are raw bytes)."""
    t = BPETokenizer([])
    data = bytes(range(256)).decode("latin-1")
    ids = t.encode(data)
    assert all(0 <= i < 256 for i in ids)
