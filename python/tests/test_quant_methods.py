"""Method-level properties of the quantization schemes (L2 dispatch).

These encode the paper's central claims as testable invariants:

* MUXQ's decomposition is an exact identity before quantization (eq. 6);
* MUXQ's Body has a strictly smaller dynamic range than X when outliers
  are present, so its per-tensor quantization error is lower than naive;
* LLM.int8() leaves outlier columns bit-exact;
* SmoothQuant migration is function-preserving in FP.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import QuantConfig
from compile.kernels import ref
from compile import quant

SEED = st.integers(0, 2**31 - 1)


def outlier_matrix(seed, m=64, n=64, cols=3, scale=25.0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, n)).astype(np.float32)
    idx = rng.choice(n, size=cols, replace=False)
    x[:, idx] *= scale
    return jnp.asarray(x), idx


@settings(deadline=None, max_examples=15)
@given(SEED)
def test_muxq_beats_naive_per_tensor(seed):
    """The headline mechanism: with genuine outlier channels, MUXQ's
    per-tensor fake-quant error is below naive's."""
    x, _ = outlier_matrix(seed)
    q = 127.0
    naive = ref.fq_naive(x, q, None)
    muxq = ref.fq_muxq(x, q, None, 6.0, 2)
    e_naive = float(jnp.mean(jnp.abs(naive - x)))
    e_muxq = float(jnp.mean(jnp.abs(muxq - x)))
    assert e_muxq < e_naive


@settings(deadline=None, max_examples=15)
@given(SEED, st.sampled_from([1, 2, 3]))
def test_muxq_identity_without_quant(seed, exp):
    x, _ = outlier_matrix(seed)
    mask = ref.outlier_mask(x, 6.0)
    body, aux = ref.muxq_decompose(x, mask, exp)
    rec = ref.muxq_reconstruct(body, aux, exp)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(x), rtol=1e-6, atol=1e-6)


def test_llmint8_outlier_columns_exact():
    x, idx = outlier_matrix(123)
    y = ref.fq_llmint8_act(x, 127.0, None, 6.0)
    np.testing.assert_array_equal(np.asarray(y)[:, idx], np.asarray(x)[:, idx])


def test_llmint8_better_than_muxq_better_than_naive_low_bits():
    """Paper Table 1 ordering at low activation precision:
    naive >> MUXQ >= LLM.int8() in error."""
    x, _ = outlier_matrix(7, cols=4, scale=30.0)
    q = 2.0 ** (6 - 1) - 1  # 6-bit activations
    err = lambda y: float(jnp.mean(jnp.abs(y - x)))
    e_naive = err(ref.fq_naive(x, q, None))
    e_muxq = err(ref.fq_muxq(x, q, None, 6.0, 2))
    e_int8 = err(ref.fq_llmint8_act(x, q, None, 6.0))
    assert e_int8 <= e_muxq < e_naive


@settings(deadline=None, max_examples=10)
@given(SEED)
def test_smoothquant_function_preserving(seed):
    """x/s @ (s*w) == x @ w in FP."""
    x, _ = outlier_matrix(seed, m=32, n=48)
    rng = np.random.default_rng(seed + 1)
    w = jnp.asarray(rng.normal(size=(48, 24)).astype(np.float32))
    s = ref.smooth_scales(jnp.max(jnp.abs(x), axis=0), w, 0.5)
    y1 = (x / s.reshape(1, -1)) @ (w * s.reshape(-1, 1))
    y2 = x @ w
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4, atol=2e-4)


def test_smoothquant_reduces_activation_range():
    x, _ = outlier_matrix(5, cols=5, scale=40.0)
    rng = np.random.default_rng(6)
    w = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    s = ref.smooth_scales(jnp.max(jnp.abs(x), axis=0), w, 0.5)
    x_s = x / s.reshape(1, -1)
    assert float(jnp.max(jnp.abs(x_s))) < float(jnp.max(jnp.abs(x)))


def test_quant_linear_dispatch_all_methods():
    x, _ = outlier_matrix(11, m=32, n=64, cols=2)
    rng = np.random.default_rng(12)
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    b = jnp.zeros((16,), jnp.float32)
    exact = np.asarray(x @ w)
    errs = {}
    for method in ("fp16", "naive", "muxq", "llmint8"):
        for gran in ("per-vector", "per-tensor"):
            qcfg = QuantConfig(method, gran)
            y = quant.quant_linear(x, w, b, qcfg, 127.0, 127.0)
            assert y.shape == (32, 16)
            errs[(method, gran)] = float(np.mean(np.abs(np.asarray(y) - exact)))
    assert errs[("fp16", "per-tensor")] == 0.0
    for gran in ("per-vector", "per-tensor"):
        assert errs[("muxq", gran)] < errs[("naive", gran)]
        assert errs[("llmint8", gran)] <= errs[("muxq", gran)] * 1.5


def test_quant_linear_int_matches_fake_quant_naive():
    """True INT pipeline == fake-quant pipeline for naive (exactness of
    scale factoring, end to end through the pallas kernels)."""
    x, _ = outlier_matrix(31, m=32, n=64, cols=2)
    rng = np.random.default_rng(32)
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    qcfg = QuantConfig("naive", "per-vector")
    y_int = quant.quant_linear_int(x, w, qcfg, 127.0, 127.0)
    y_fq = quant.quant_linear(x, w, None, qcfg, 127.0, 127.0)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_fq), rtol=1e-5, atol=1e-4)


def test_quant_linear_int_muxq_two_gemm_equals_fused():
    """Paper eq. 7: Y = Body·W + (2^exp − 1)·Aux·W reproduces the
    fake-quant MUXQ result."""
    x, _ = outlier_matrix(41, m=32, n=64, cols=3)
    rng = np.random.default_rng(42)
    w = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    qcfg = QuantConfig("muxq", "per-tensor")
    y_int = quant.quant_linear_int(x, w, qcfg, 127.0, 127.0)
    y_fq = quant.quant_linear(x, w, None, qcfg, 127.0, 127.0)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_fq), rtol=1e-5, atol=1e-4)


@settings(deadline=None, max_examples=10)
@given(SEED, st.sampled_from([5.0, 6.0, 7.0, 8.0]))
def test_error_monotone_in_bits_muxq(seed, bits):
    x, _ = outlier_matrix(seed)
    q_lo = 2.0 ** (bits - 1) - 1
    q_hi = 2.0 ** bits - 1  # one more bit
    e_lo = float(jnp.mean(jnp.abs(ref.fq_muxq(x, q_lo, None, 6.0, 2) - x)))
    e_hi = float(jnp.mean(jnp.abs(ref.fq_muxq(x, q_hi, None, 6.0, 2) - x)))
    assert e_hi <= e_lo + 1e-7


def test_expfactor_tradeoff():
    """Higher exp_factor shrinks Body range (better body quant) but
    amplifies Aux quantization error by (2^exp - 1) — the §3.3 trade-off."""
    x, _ = outlier_matrix(51, cols=3, scale=30.0)
    q = 127.0
    mask = ref.outlier_mask(x, 6.0)
    ranges = []
    for e in (1, 2, 3, 4):
        body, _ = ref.muxq_decompose(x, mask, e)
        ranges.append(float(jnp.max(jnp.abs(body))))
    assert ranges == sorted(ranges, reverse=True)
