"""Calibration + training-pipeline tests (tiny configs)."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.calibrate import (capture_absmax, outlier_stats,
                               smooth_scales_per_block)
from compile.config import ModelConfig
from compile.kernels import ref
from compile.model import PROJ_SITES, forward, init_params, inject_outliers
from compile.train import adamw_init, adamw_update, batches, cosine_lr, train

CFG = ModelConfig("t", n_layer=2, d_model=32, n_head=2, n_ctx=16,
                  vocab_size=64, train_steps=8, train_batch=4, lr=1e-2)


@pytest.fixture(scope="module")
def params():
    return inject_outliers(init_params(CFG, seed=0), CFG, 3, 12.0)


@pytest.fixture(scope="module")
def calib_batches():
    rng = np.random.default_rng(0)
    return [rng.integers(0, 64, size=(2, 16)).astype(np.int32) for _ in range(2)]


def test_capture_covers_all_sites(params, calib_batches):
    absmax = capture_absmax(params, CFG, calib_batches)
    assert len(absmax) == CFG.n_layer * 4
    for (li, site), v in absmax.items():
        assert 0 <= li < CFG.n_layer
        assert site in PROJ_SITES
        expected = CFG.d_ff if site == "mlp_proj" else CFG.d_model
        assert v.shape == (expected,)
        assert np.all(v >= 0)


def test_capture_is_running_max(params, calib_batches):
    both = capture_absmax(params, CFG, calib_batches)
    first = capture_absmax(params, CFG, calib_batches[:1])
    for key in both:
        assert np.all(both[key] >= first[key] - 1e-6)


def test_outlier_stats_detects_injection(params, calib_batches):
    absmax = capture_absmax(params, CFG, calib_batches)
    stats = outlier_stats(absmax, theta=6.0)
    # injection targets the two post-LN sites
    assert stats[(0, "c_fc")]["outliers"] >= 1
    assert stats[(0, "c_attn")]["outliers"] >= 1
    for v in stats.values():
        assert v["max"] >= v["median"]


def test_smooth_scales_shapes_and_positivity(params, calib_batches):
    absmax = capture_absmax(params, CFG, calib_batches)
    smooth = smooth_scales_per_block(params, CFG, absmax, alpha=0.5)
    assert len(smooth) == CFG.n_layer
    for li, per_site in enumerate(smooth):
        for site in PROJ_SITES:
            s = per_site[site]
            assert np.all(s > 0) and np.all(np.isfinite(s))


def test_smooth_migration_preserves_model_output(params, calib_batches):
    """Baking s into (x/s, s*w) must preserve the FP forward through a
    real projection: verified at the first c_fc."""
    absmax = capture_absmax(params, CFG, calib_batches)
    smooth = smooth_scales_per_block(params, CFG, absmax, alpha=0.5)
    s = jnp.asarray(smooth[0]["c_fc"])
    w = params["blocks"][0]["c_fc"]["w"]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, CFG.d_model)).astype(np.float32))
    y0 = x @ w
    y1 = (x / s) @ (w * s[:, None])
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------- training
def test_batches_shapes():
    ids = np.arange(1000, dtype=np.int32)
    bs = list(batches(ids, CFG, steps=3, seed=0))
    assert len(bs) == 3
    for b in bs:
        assert b.shape == (CFG.train_batch, CFG.n_ctx)
        assert b.dtype == np.int32


def test_batches_too_small_corpus():
    with pytest.raises(ValueError):
        list(batches(np.arange(4, dtype=np.int32), CFG, steps=1))


def test_cosine_lr_schedule():
    import jax
    lrs = [float(cosine_lr(1.0, jnp.asarray(float(s)), total=100, warmup=10))
           for s in range(100)]
    assert lrs[0] < lrs[9]            # warmup rises
    assert abs(lrs[10] - 1.0) < 0.02  # peak after warmup
    assert lrs[-1] < 0.01             # decays to ~0


def test_adamw_moves_params_toward_gradient():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.asarray([1.0, -1.0, 0.5, 0.0])}
    opt = adamw_init(params)
    new, _ = adamw_update(params, grads, opt, lr=0.1, weight_decay=0.0)
    # sign of update opposes gradient
    assert float(new["w"][0]) < 1.0
    assert float(new["w"][1]) > 1.0
    assert float(new["w"][3]) == pytest.approx(1.0, abs=1e-6)


def test_short_training_run_decreases_loss():
    rng = np.random.default_rng(0)
    # learnable synthetic stream: repeating pattern
    ids = np.tile(rng.integers(0, 64, size=200), 20).astype(np.int32)
    res = train(CFG, ids, log=lambda *a: None)
    assert res.steps == CFG.train_steps
    first_loss = res.loss_curve[0][1]
    assert res.final_loss < first_loss
