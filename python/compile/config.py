"""Model + quantization configuration shared across the build pipeline.

The three ``sim-*`` configs are the scaled-down stand-ins for the paper's
GPT-2 small/medium/large (see DESIGN.md §2 — pretrained HF checkpoints are
unavailable in this environment, so the models are trained at build time).
The real GPT-2 configs are kept for users who have checkpoints.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layer: int
    d_model: int
    n_head: int
    n_ctx: int
    vocab_size: int
    #: training steps at build time (0 for configs we never train here)
    train_steps: int = 0
    train_batch: int = 16
    lr: float = 3e-3

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_head == 0
        return self.d_model // self.n_head

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def param_count(self) -> int:
        d, v, L = self.d_model, self.vocab_size, self.n_layer
        per_block = (
            d * 3 * d + 3 * d      # c_attn
            + d * d + d            # attn c_proj
            + d * self.d_ff + self.d_ff  # c_fc
            + self.d_ff * d + d    # mlp c_proj
            + 4 * d                # two layernorms
        )
        return v * d + self.n_ctx * d + L * per_block + 2 * d


#: BPE vocab: 256 bytes + 256 merges
SIM_VOCAB = 512

MODELS = {
    "sim-small": ModelConfig("sim-small", n_layer=4, d_model=128, n_head=4,
                             n_ctx=128, vocab_size=SIM_VOCAB,
                             train_steps=700, lr=3e-3),
    "sim-medium": ModelConfig("sim-medium", n_layer=6, d_model=192, n_head=6,
                              n_ctx=128, vocab_size=SIM_VOCAB,
                              train_steps=900, lr=2.5e-3),
    "sim-large": ModelConfig("sim-large", n_layer=8, d_model=256, n_head=8,
                             n_ctx=128, vocab_size=SIM_VOCAB,
                             train_steps=1400, lr=2e-3),
    # Real GPT-2 configs (not trained here; for users with checkpoints).
    "gpt2-small": ModelConfig("gpt2-small", 12, 768, 12, 1024, 50257),
    "gpt2-medium": ModelConfig("gpt2-medium", 24, 1024, 16, 1024, 50257),
    "gpt2-large": ModelConfig("gpt2-large", 36, 1280, 20, 1024, 50257),
}

SIM_MODELS = ["sim-small", "sim-medium", "sim-large"]


@dataclass(frozen=True)
class QuantConfig:
    """One quantization *variant* — a (method, granularity, options) point.

    Bit-widths are deliberately NOT part of the variant: they are runtime
    scalar inputs of the exported HLO so one executable serves the whole
    bit sweep of Tables 1–2.
    """

    #: 'fp16' | 'naive' | 'muxq' | 'llmint8'
    method: str = "fp16"
    #: 'per-vector' (per-token IA, per-out-channel W) | 'per-tensor'
    granularity: str = "per-tensor"
    #: outlier threshold (LLM.int8() criterion: any |x| > theta)
    theta: float = 6.0
    #: MUXQ exponent shift: Body = X / 2^exp_factor
    exp_factor: int = 2
    #: apply SmoothQuant difficulty migration before quantizing
    smooth: bool = False
    #: SmoothQuant alpha
    smooth_alpha: float = 0.5
    #: blockwise-orthogonal rotation pre-transform (DuQuant-style; the
    #: rust engine owns the algebra — here only the variant spelling)
    rotate: bool = False
    #: zigzag channel-permutation pre-transform
    permute: bool = False
    #: explicit resq residual rank (``-r{N}``; resq-only, None = auto)
    resid_rank: int | None = None

    @property
    def tag(self) -> str:
        """Canonical variant tag — MUST stay in sync with the rust
        ``EngineSpec::tag`` grammar (pre-transform suffixes in pipeline
        order smooth -> rotate -> permute, then ``-r{N}``/``-e{N}``);
        ``Manifest::load`` rejects entries whose fields drift from it."""
        g = "pv" if self.granularity == "per-vector" else "pt"
        s = ("-sq" if self.smooth else "") \
            + ("-rot" if self.rotate else "") \
            + ("-perm" if self.permute else "")
        r = f"-r{self.resid_rank}" if self.method == "resq" and self.resid_rank else ""
        e = f"-e{self.exp_factor}" if self.method == "muxq" and self.exp_factor != 2 else ""
        return f"{self.method}-{g}{s}{r}{e}"


#: variants exported per sim model (Tables 1, 2 + combos)
EXPORT_VARIANTS = [
    QuantConfig("fp16", "per-tensor"),
    QuantConfig("naive", "per-vector"),
    QuantConfig("naive", "per-tensor"),
    QuantConfig("muxq", "per-vector"),
    QuantConfig("muxq", "per-tensor"),
    QuantConfig("llmint8", "per-vector"),
    QuantConfig("llmint8", "per-tensor"),
    QuantConfig("muxq", "per-tensor", smooth=True),
    QuantConfig("naive", "per-tensor", smooth=True),
]

#: eval batch geometry baked into exported HLO (rust pads to this)
EVAL_BATCH = 8
EVAL_SEQ = 128

#: outlier injection (DESIGN.md §2): k channels scaled by alpha,
#: function-preserving (consuming projection rows scaled by 1/alpha)
INJECT_CHANNELS = 6
INJECT_ALPHA = 12.0
