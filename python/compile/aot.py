"""AOT build orchestrator (``make artifacts``).

Pipeline (each stage skipped when its outputs already exist, so the
Makefile target is an incremental no-op):

1. corpus      — synthetic WikiText-like train/valid splits
2. tokenizer   — byte-level BPE (256 merges), token caches
3. training    — the three sim GPT-2 models (FP32, build-time)
4. injection   — function-preserving outlier injection
5. calibration — per-site activation abs-max, SmoothQuant scales
6. export      — HLO *text* per (model, variant): eval + logits graphs
7. goldens     — oracles for the rust quantization twin & runtime tests

HLO text (not serialized proto) is the interchange format: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Weights are HLO *inputs*, not constants (keeps HLO text small and lets
every variant share one weights file). Input order contract with rust:
all weights.bin tensors in byte-sorted name order, then tokens i32[B,S],
ia_bits f32[], w_bits f32[].
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import bpe as bpe_mod
from . import quant
from .calibrate import (calib_tensors, capture_absmax, outlier_stats,
                        smooth_scales_per_block, smooth_tensors)
from .config import (EVAL_BATCH, EVAL_SEQ, EXPORT_VARIANTS, INJECT_ALPHA,
                     INJECT_CHANNELS, MODELS, SIM_MODELS, ModelConfig,
                     QuantConfig)
from .corpus import generate
from .iohelpers import params_to_tensors, read_tensors, tensors_to_params, write_tensors
from .kernels import ref
from .model import forward, inject_outliers, nll_per_seq, nll_sums
from .train import train

#: extra ablation variants, exported for sim-small only (Fig.4 trade-off)
ABLATION_VARIANTS = [
    QuantConfig("muxq", "per-tensor", exp_factor=1),
    QuantConfig("muxq", "per-tensor", exp_factor=3),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ----------------------------------------------------------------- stages
def stage_corpus(root: Path, log) -> tuple:
    cdir = root / "corpus"
    train_p, valid_p = cdir / "train.txt", cdir / "valid.txt"
    if train_p.exists() and valid_p.exists():
        return train_p.read_text(), valid_p.read_text()
    log("[corpus] generating synthetic WikiText-like corpus...")
    train_text, valid_text = generate()
    cdir.mkdir(parents=True, exist_ok=True)
    train_p.write_text(train_text)
    valid_p.write_text(valid_text)
    log(f"[corpus] train {len(train_text)/1e6:.2f} MB, valid {len(valid_text)/1e3:.0f} KB")
    return train_text, valid_text


def stage_tokenizer(root: Path, train_text: str, valid_text: str, log):
    cdir = root / "corpus"
    tok_p = cdir / "tokenizer.bpe"
    tok_cache = cdir / "tokens.bin"
    if tok_p.exists() and tok_cache.exists():
        tok = bpe_mod.BPETokenizer.load(tok_p.read_text())
        t = read_tensors(tok_cache)
        return tok, t["train"], t["valid"]
    log("[bpe] training byte-level BPE (256 merges)...")
    tok = bpe_mod.train(train_text, n_merges=256)
    tok_p.write_text(tok.dump())
    log("[bpe] encoding corpus...")
    train_ids = np.asarray(tok.encode(train_text), np.int32)
    valid_ids = np.asarray(tok.encode(valid_text), np.int32)
    write_tensors(tok_cache, {"train": train_ids, "valid": valid_ids})
    log(f"[bpe] vocab {tok.vocab_size}, train {len(train_ids)} tokens, "
        f"valid {len(valid_ids)} tokens")
    return tok, train_ids, valid_ids


def stage_model(root: Path, cfg: ModelConfig, train_ids, valid_ids, log) -> dict:
    wdir = root / "weights"
    wpath = wdir / f"{cfg.name}.bin"
    if wpath.exists():
        flat = read_tensors(wpath)
        n_layer = cfg.n_layer
        weights = {k: v for k, v in flat.items() if not k.startswith("smooth/")}
        return tensors_to_params(weights, n_layer) | {"_flat": flat}
    log(f"[train] {cfg.name}: {cfg.n_layer}L d={cfg.d_model} "
        f"({cfg.param_count()/1e6:.2f}M params), {cfg.train_steps} steps")
    res = train(cfg, train_ids, log=log)
    log(f"[train] {cfg.name} done in {res.seconds:.0f}s, final loss {res.final_loss:.4f}")

    params = inject_outliers(res.params, cfg, INJECT_CHANNELS, INJECT_ALPHA)

    # calibration on valid windows
    calib = [np.stack([valid_ids[i * EVAL_SEQ:(i + 1) * EVAL_SEQ]
                       for i in range(b * EVAL_BATCH, (b + 1) * EVAL_BATCH)]).astype(np.int32)
             for b in range(2)]
    absmax = capture_absmax(params, cfg, calib)
    stats = outlier_stats(absmax)
    worst = max(stats.values(), key=lambda s: s["max"])
    log(f"[calib] {cfg.name}: worst site max|x|={worst['max']:.1f}, "
        f"outlier channels (theta=6) at c_fc/l0: "
        f"{stats[(0,'c_fc')]['outliers']}/{stats[(0,'c_fc')]['channels']}")
    smooth = smooth_scales_per_block(params, cfg, absmax, alpha=0.5)

    flat = params_to_tensors(params) | smooth_tensors(smooth)
    write_tensors(wpath, flat)
    write_tensors(root / "calib" / f"{cfg.name}.bin", calib_tensors(absmax))
    (root / "train_logs").mkdir(parents=True, exist_ok=True)
    (root / "train_logs" / f"{cfg.name}.json").write_text(json.dumps({
        "final_loss": res.final_loss, "steps": res.steps,
        "seconds": res.seconds, "curve": res.loss_curve,
        "outlier_stats": {f"{li}/{site}": v for (li, site), v in stats.items()},
    }, indent=1))
    return params | {"_flat": flat}


def sorted_weight_names(flat: dict) -> list:
    return sorted(k for k in flat if k != "_flat")


def _smooth_from_flat(flat: dict, n_layer: int) -> list:
    out = []
    for li in range(n_layer):
        per_site = {}
        for site in ("c_attn", "attn_proj", "c_fc", "mlp_proj"):
            key = f"smooth/block{li:02d}/{site}"
            if key in flat:
                per_site[site] = jnp.asarray(flat[key])
        out.append(per_site)
    return out


def build_eval_fn(cfg: ModelConfig, qcfg: QuantConfig, names: list, kind: str):
    """Returns fn(*weights, tokens, ia_bits, w_bits) for jax.jit export.
    kind: 'eval' -> (nll_sum, count); 'logits' -> logits."""

    def fn(*args):
        ws, tokens, ia_bits, w_bits = args[:-3], args[-3], args[-2], args[-1]
        flat = dict(zip(names, ws))
        weights = {k: v for k, v in flat.items() if not k.startswith("smooth/")}
        params = tensors_to_params(weights, cfg.n_layer)
        smooth = _smooth_from_flat(flat, cfg.n_layer) if qcfg.smooth else None
        kw = dict(qcfg=qcfg, ia_bits=ia_bits, w_bits=w_bits,
                  smooth_per_block=smooth)
        if kind == "eval":
            s, c = nll_per_seq(params, tokens, cfg, **kw)
            return (s, c)
        return (forward(params, tokens, cfg, **kw),)

    return fn


def stage_export(root: Path, cfg: ModelConfig, flat: dict, variants, log,
                 kinds=("eval",)) -> list:
    hdir = root / "hlo"
    hdir.mkdir(parents=True, exist_ok=True)
    names = sorted_weight_names(flat)
    specs = [jax.ShapeDtypeStruct(flat[n].shape, jnp.float32) for n in names]
    tok_spec = jax.ShapeDtypeStruct((EVAL_BATCH, EVAL_SEQ), jnp.int32)
    bit_spec = jax.ShapeDtypeStruct((), jnp.float32)
    manifest = []
    for qcfg in variants:
        for kind in kinds:
            out = hdir / f"{cfg.name}-{kind}-{qcfg.tag}.hlo.txt"
            manifest.append({
                "model": cfg.name, "kind": kind, "tag": qcfg.tag,
                "method": qcfg.method, "granularity": qcfg.granularity,
                "smooth": qcfg.smooth, "exp_factor": qcfg.exp_factor,
                "rotate": qcfg.rotate, "permute": qcfg.permute,
                "file": out.name, "batch": EVAL_BATCH, "seq": EVAL_SEQ,
                "weights": f"weights/{cfg.name}.bin",
            })
            if out.exists():
                continue
            t0 = time.time()
            fn = build_eval_fn(cfg, qcfg, names, kind)
            lowered = jax.jit(fn, keep_unused=True).lower(*specs, tok_spec, bit_spec, bit_spec)
            text = to_hlo_text(lowered)
            out.write_text(text)
            log(f"[export] {out.name}: {len(text)/1e6:.1f} MB HLO text "
                f"({time.time()-t0:.1f}s)")
    return manifest


def stage_goldens(root: Path, log) -> None:
    """Oracles for the rust quantization twin (rust/src/quant tests)."""
    gpath = root / "goldens" / "quant.bin"
    if gpath.exists():
        return
    log("[goldens] generating quantization oracles for rust cross-check...")
    rng = np.random.default_rng(42)
    x = rng.normal(size=(64, 96)).astype(np.float32)
    x[:, 7] *= 25.0  # outlier channels
    x[:, 40] *= 14.0
    w = rng.normal(size=(96, 32)).astype(np.float32)
    g: dict = {"x": x, "w": w}
    q8 = 127.0
    for gran, axx, axw in (("pt", None, None), ("pv", 1, 0)):
        sx = np.asarray(ref.absmax_scale(jnp.asarray(x), q8, axis=axx)).reshape(
            (-1, 1) if axx == 1 else (1, 1))
        sw = np.asarray(ref.absmax_scale(jnp.asarray(w), q8, axis=axw)).reshape(
            (1, -1) if axw == 0 else (1, 1))
        g[f"fq_naive_x_{gran}"] = np.asarray(ref.fake_quant(jnp.asarray(x), jnp.asarray(sx), q8))
        g[f"fq_naive_w_{gran}"] = np.asarray(ref.fake_quant(jnp.asarray(w), jnp.asarray(sw), q8))
        g[f"qmm_{gran}"] = np.asarray(ref.quant_matmul(
            jnp.asarray(x), jnp.asarray(w), jnp.asarray(sx), jnp.asarray(sw), q8, q8))
        g[f"fq_muxq_x_{gran}"] = np.asarray(ref.fq_muxq(jnp.asarray(x), q8, axx, 6.0, 2))
        g[f"fq_llmint8_x_{gran}"] = np.asarray(ref.fq_llmint8_act(jnp.asarray(x), q8, axx, 6.0))
    mask = np.asarray(ref.outlier_mask(jnp.asarray(x), 6.0))
    g["outlier_mask"] = mask.astype(np.float32)
    body, aux = ref.muxq_decompose(jnp.asarray(x), jnp.asarray(mask), 2)
    g["muxq_body"] = np.asarray(body)
    g["muxq_aux"] = np.asarray(aux)
    # 4-bit variants for the low-bit paths
    q4 = 7.0
    s4 = np.asarray(ref.absmax_scale(jnp.asarray(x), q4, axis=None)).reshape(1, 1)
    g["fq_naive_x_pt_4b"] = np.asarray(ref.fake_quant(jnp.asarray(x), jnp.asarray(s4), q4))
    g["smooth_s"] = np.asarray(ref.smooth_scales(
        jnp.asarray(np.abs(x).max(axis=0)), jnp.asarray(w), 0.5))
    write_tensors(gpath, g)


def stage_eval_goldens(root: Path, cfg: ModelConfig, flat: dict, valid_ids,
                       variants, log) -> None:
    """Per-variant (nll, count) on one fixed batch — used by rust
    integration tests to validate the whole PJRT path end to end."""
    gpath = root / "goldens" / f"eval_{cfg.name}.bin"
    if gpath.exists():
        return
    tokens = np.stack([valid_ids[i * EVAL_SEQ:(i + 1) * EVAL_SEQ]
                       for i in range(EVAL_BATCH)]).astype(np.int32)
    weights = {k: v for k, v in flat.items() if not k.startswith("smooth/") and k != "_flat"}
    params = tensors_to_params(weights, cfg.n_layer)
    smooth = _smooth_from_flat(flat, cfg.n_layer)
    g: dict = {"tokens": tokens}
    for qcfg in variants:
        s, c = nll_sums(params, jnp.asarray(tokens), cfg, qcfg=qcfg,
                        ia_bits=8.0, w_bits=8.0,
                        smooth_per_block=smooth if qcfg.smooth else None)
        g[f"nll/{qcfg.tag}"] = np.asarray([float(s), float(c)], np.float32)
        log(f"[golden] {cfg.name} {qcfg.tag}: ppl(8,8) = {np.exp(float(s)/float(c)):.4f}")
    write_tensors(gpath, g)


# ------------------------------------------------------------------- main
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="MUXQ AOT artifact builder")
    ap.add_argument("--out", default=None, help="(legacy) single-HLO output path")
    ap.add_argument("--root", default=None, help="artifacts root")
    ap.add_argument("--models", nargs="*", default=SIM_MODELS)
    ap.add_argument("--no-pallas", action="store_true",
                    help="use jnp reference instead of pallas kernels")
    args = ap.parse_args(argv)

    if args.no_pallas:
        quant.USE_PALLAS = False

    root = Path(args.root) if args.root else Path(__file__).resolve().parents[2] / "artifacts"
    root.mkdir(parents=True, exist_ok=True)
    log = lambda *a: print(*a, flush=True)

    t_start = time.time()
    train_text, valid_text = stage_corpus(root, log)
    tok, train_ids, valid_ids = stage_tokenizer(root, train_text, valid_text, log)
    stage_goldens(root, log)

    manifest: list = []
    for name in args.models:
        cfg = MODELS[name]
        params = stage_model(root, cfg, train_ids, valid_ids, log)
        flat = params["_flat"]
        variants = list(EXPORT_VARIANTS)
        kinds = ("eval",)
        manifest += stage_export(root, cfg, flat, variants, log, kinds=kinds)
        # logits graphs for the serving example (fp16 + muxq-pt)
        manifest += stage_export(root, cfg, flat,
                                 [QuantConfig("fp16", "per-tensor"),
                                  QuantConfig("muxq", "per-tensor")],
                                 log, kinds=("logits",))
        if name == "sim-small":
            manifest += stage_export(root, cfg, flat, ABLATION_VARIANTS, log)
        stage_eval_goldens(root, cfg, flat, valid_ids, variants, log)

    (root / "manifest.json").write_text(json.dumps(manifest, indent=1))
    # legacy single-file target used by the Makefile stamp
    if args.out:
        stamp = Path(args.out)
        stamp.parent.mkdir(parents=True, exist_ok=True)
        stamp.write_text(f"# muxq artifacts built in {time.time()-t_start:.0f}s; "
                         f"see manifest.json\n")
    log(f"[aot] all artifacts ready in {time.time()-t_start:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
