"""Calibration pass: per-site activation statistics.

Runs the FP model over a few calibration batches and records, for each
(block, projection-site), the per-channel abs-max of the input
activations. These feed

* the SmoothQuant migration scales (stored into weights.bin as
  ``smooth/blockNN/<site>`` so the exported HLO takes them as inputs);
* Figure 1 (outlier channel magnitude profile) via
  ``artifacts/calib/<model>.bin`` (``absmax/blockNN/<site>``).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, QuantConfig
from .kernels import ref
from .model import PROJ_SITES, forward

#: weight matrix feeding each capture site
SITE_WEIGHT = {"c_attn": "c_attn", "attn_proj": "attn_proj",
               "c_fc": "c_fc", "mlp_proj": "mlp_proj"}


def capture_absmax(params: dict, cfg: ModelConfig, token_batches) -> dict:
    """Returns {(layer, site): np.ndarray[K]} — running abs-max across
    calibration batches."""
    agg: dict = {}
    for batch in token_batches:
        cap: dict = {}
        forward(params, jnp.asarray(batch), cfg, capture=cap)
        for key, vec in cap.items():
            v = np.asarray(vec)
            agg[key] = np.maximum(agg[key], v) if key in agg else v
    return agg


def smooth_scales_per_block(params: dict, cfg: ModelConfig, absmax: dict,
                            alpha: float) -> list:
    """SmoothQuant migration scales s_j per (block, site)."""
    out = []
    for li, blk in enumerate(params["blocks"]):
        per_site = {}
        for site in PROJ_SITES:
            w = blk[SITE_WEIGHT[site]]["w"]
            am = jnp.asarray(absmax[(li, site)])
            per_site[site] = np.asarray(ref.smooth_scales(am, w, alpha))
        out.append(per_site)
    return out


def calib_tensors(absmax: dict) -> dict:
    """Flatten capture dict for the tensor container."""
    return {f"absmax/block{li:02d}/{site}": np.asarray(v, np.float32)
            for (li, site), v in sorted(absmax.items(), key=lambda kv: (kv[0][0], kv[0][1]))}


def smooth_tensors(smooth_per_block: list) -> dict:
    out = {}
    for li, per_site in enumerate(smooth_per_block):
        for site, s in per_site.items():
            out[f"smooth/block{li:02d}/{site}"] = np.asarray(s, np.float32)
    return out


def outlier_stats(absmax: dict, theta: float = 6.0) -> dict:
    """Summary used in EXPERIMENTS.md: outlier channel counts per site."""
    stats = {}
    for (li, site), v in absmax.items():
        n_out = int((v > theta).sum())
        stats[(li, site)] = {
            "channels": int(v.size),
            "outliers": n_out,
            "max": float(v.max()),
            "median": float(np.median(v)),
        }
    return stats
