"""Deterministic synthetic WikiText-like corpus.

WikiText-2 is unavailable offline, so we substitute a generated corpus that
preserves the *statistical properties that matter for language-model
perplexity comparisons*:

* Zipfian unigram distribution over a closed vocabulary of pronounceable
  words (so the model has both very frequent and rare tokens);
* first-order Markov structure (each word has a small, fixed successor set)
  so there is real signal for a causal LM to learn — FP perplexity lands
  well below the uniform baseline and quantization damage is measurable;
* WikiText surface form: ``= Heading =`` lines, paragraphs, sentence
  casing and punctuation, so the byte-level BPE tokenizer sees realistic
  byte patterns.

Everything is driven by :class:`~compile.prng.SplitMix64`, mirrored in
``rust/src/data/corpus.rs``; a golden test pins the first bytes of the
stream on both sides.
"""

from __future__ import annotations

from dataclasses import dataclass

from .prng import SplitMix64, mix, zipf_index

SYLLABLES = [
    "ka", "ro", "mi", "ten", "sol", "ar", "ven", "da", "lu", "per",
    "no", "ti", "gra", "bel", "os", "un", "ser", "al", "cor", "em",
    "fa", "ri", "qua", "sto", "ne", "il", "tur", "ba", "che", "mon",
]

#: number of candidate successors per word (Markov branching factor)
SUCCESSORS = 24


@dataclass(frozen=True)
class CorpusConfig:
    seed: int = 0x5EED_2026
    vocab_words: int = 1500
    articles: int = 120
    paragraphs_per_article: tuple = (3, 7)
    sentences_per_paragraph: tuple = (2, 6)
    words_per_sentence: tuple = (4, 18)
    zipf_s: float = 1.05


def make_word(word_id: int, seed: int) -> str:
    """Deterministically build a pronounceable word from its id."""
    h = mix(seed, word_id)
    rng = SplitMix64(h)
    n_syll = 2 + rng.next_below(3)  # 2..4 syllables
    parts = [SYLLABLES[rng.next_below(len(SYLLABLES))] for _ in range(n_syll)]
    return "".join(parts)


class CorpusGenerator:
    """Generates the train/valid splits. The valid split uses a disjoint
    seed stream so it is not a memorized subset of train."""

    def __init__(self, cfg: CorpusConfig | None = None) -> None:
        self.cfg = cfg or CorpusConfig()
        self.words = [make_word(i, self.cfg.seed) for i in range(self.cfg.vocab_words)]

    def _successors(self, word_id: int) -> list:
        """Fixed successor set for ``word_id`` (first-order Markov)."""
        h = mix(self.cfg.seed, 0xA11CE, word_id)
        rng = SplitMix64(h)
        return [rng.next_below(self.cfg.vocab_words) for _ in range(SUCCESSORS)]

    def _sentence(self, rng: SplitMix64, cur: int) -> tuple:
        lo, hi = self.cfg.words_per_sentence
        n = rng.next_range(lo, hi)
        out = []
        for _ in range(n):
            succ = self._successors(cur)
            cur = succ[zipf_index(rng, SUCCESSORS, self.cfg.zipf_s)]
            out.append(self.words[cur])
        s = " ".join(out)
        s = s[0].upper() + s[1:] + "."
        return s, cur

    def _title(self, rng: SplitMix64) -> str:
        n = rng.next_range(1, 3)
        ws = [self.words[zipf_index(rng, self.cfg.vocab_words, self.cfg.zipf_s)] for _ in range(n)]
        return " ".join(w.capitalize() for w in ws)

    def article(self, rng: SplitMix64) -> str:
        lines = [f"= {self._title(rng)} =", ""]
        cur = zipf_index(rng, self.cfg.vocab_words, self.cfg.zipf_s)
        p_lo, p_hi = self.cfg.paragraphs_per_article
        s_lo, s_hi = self.cfg.sentences_per_paragraph
        for _ in range(rng.next_range(p_lo, p_hi)):
            sents = []
            for _ in range(rng.next_range(s_lo, s_hi)):
                s, cur = self._sentence(rng, cur)
                sents.append(s)
            lines.append(" ".join(sents))
            lines.append("")
        return "\n".join(lines)

    def split(self, name: str, articles: int | None = None) -> str:
        """Generate a named split ('train' | 'valid' | anything)."""
        stream_seed = mix(self.cfg.seed, sum(ord(c) for c in name), len(name))
        rng = SplitMix64(stream_seed)
        n = articles if articles is not None else self.cfg.articles
        return "\n".join(self.article(rng) for _ in range(n))


def generate(cfg: CorpusConfig | None = None) -> tuple:
    """Returns (train_text, valid_text)."""
    gen = CorpusGenerator(cfg)
    train = gen.split("train")
    valid = gen.split("valid", articles=max(4, (cfg or CorpusConfig()).articles // 10))
    return train, valid
