"""Byte-level BPE tokenizer (train + encode + decode).

Mirrored by ``rust/src/data/bpe.rs`` (encode/decode only — training happens
once at build time here, and the merge table ships in
``artifacts/corpus/tokenizer.bpe``).

Design: classic byte-level BPE a la GPT-2, but without the regex pre-split
(our synthetic corpus is plain ASCII): the corpus is split on whitespace
into words (the space is attached to the *following* word as in GPT-2's
"Ġ" convention, here kept literally as a leading space byte), merges are
learned over the word-frequency table, and encoding greedily applies merges
by rank.

Token id space: 0..255 are raw bytes, 256..256+n_merges are merge tokens.
"""

from __future__ import annotations

from collections import Counter


class BPETokenizer:
    def __init__(self, merges: list) -> None:
        #: list of ((left_id, right_id)) in training order; rank = index
        self.merges = list(merges)
        self.rank = {pair: i for i, pair in enumerate(self.merges)}
        #: token id -> bytes
        self.vocab = [bytes([i]) for i in range(256)]
        for left, right in self.merges:
            self.vocab.append(self.vocab[left] + self.vocab[right])
        self._word_cache: dict = {}

    # ------------------------------------------------------------------ api
    @property
    def vocab_size(self) -> int:
        return len(self.vocab)

    def encode_word(self, word: bytes) -> list:
        """Encode one pre-split word (greedy lowest-rank merge first)."""
        cached = self._word_cache.get(word)
        if cached is not None:
            return list(cached)
        seq = list(word)
        while len(seq) > 1:
            best_rank = None
            best_i = -1
            for i in range(len(seq) - 1):
                r = self.rank.get((seq[i], seq[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank = r
                    best_i = i
            if best_rank is None:
                break
            seq[best_i: best_i + 2] = [256 + best_rank]
        self._word_cache[word] = tuple(seq)
        return seq

    def encode(self, text: str) -> list:
        ids: list = []
        for word in split_words(text):
            ids.extend(self.encode_word(word))
        return ids

    def decode(self, ids: list) -> str:
        return b"".join(self.vocab[i] for i in ids).decode("utf-8", errors="replace")

    # ------------------------------------------------------------ serialize
    def dump(self) -> str:
        lines = ["#muxq-bpe-v1"]
        lines += [f"{l} {r}" for l, r in self.merges]
        return "\n".join(lines) + "\n"

    @classmethod
    def load(cls, text: str) -> "BPETokenizer":
        lines = [ln for ln in text.strip().splitlines() if ln and not ln.startswith("#")]
        merges = []
        for ln in lines:
            l, r = ln.split()
            merges.append((int(l), int(r)))
        return cls(merges)


def split_words(text: str) -> list:
    """Split text into byte 'words'. Whitespace is attached to the
    following word (GPT-2 style) so decode(encode(x)) == x. Newlines are
    standalone tokens-in-waiting so document structure survives."""
    out: list = []
    buf = bytearray()
    pending_space = bytearray()
    for ch in text.encode("utf-8"):
        if ch == 0x0A:  # newline: flush word, newline is its own word
            if buf:
                out.append(bytes(buf))
                buf.clear()
            if pending_space:
                out.append(bytes(pending_space))
                pending_space.clear()
            out.append(b"\n")
        elif ch == 0x20:
            if buf:
                out.append(bytes(buf))
                buf.clear()
            pending_space.append(ch)
        else:
            if pending_space:
                buf.extend(pending_space)
                pending_space.clear()
            buf.append(ch)
    if buf:
        out.append(bytes(buf))
    if pending_space:
        out.append(bytes(pending_space))
    return out


def train(text: str, n_merges: int = 256) -> BPETokenizer:
    """Learn ``n_merges`` merges from word frequencies (standard BPE)."""
    word_freq = Counter(split_words(text))
    # each word is a mutable token sequence
    words = [(list(w), f) for w, f in word_freq.items()]
    merges: list = []
    for step in range(n_merges):
        pair_freq: Counter = Counter()
        for seq, f in words:
            for i in range(len(seq) - 1):
                pair_freq[(seq[i], seq[i + 1])] += f
        if not pair_freq:
            break
        # deterministic tie-break: highest count, then smallest pair ids
        best = min(pair_freq.items(), key=lambda kv: (-kv[1], kv[0]))[0]
        if pair_freq[best] < 2:
            break
        new_id = 256 + len(merges)
        merges.append(best)
        for seq, _f in words:
            i = 0
            while i < len(seq) - 1:
                if seq[i] == best[0] and seq[i + 1] == best[1]:
                    seq[i: i + 2] = [new_id]
                else:
                    i += 1
    return BPETokenizer(merges)
