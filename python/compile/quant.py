"""L2 quantization ops: method dispatch over the L1 Pallas kernels.

This is the glue between the model (which sees one ``quant_linear``
entry point) and the kernels. Responsibilities:

* compute scales / outlier masks (cheap reductions, left to XLA so they
  fuse with surrounding ops);
* dispatch on method (fp16 | naive | muxq | llmint8) and granularity
  (per-vector | per-tensor);
* optionally apply the SmoothQuant difficulty migration first;
* call the Pallas kernels for the bandwidth-bound transforms
  (fake-quant, MUXQ decomposition).

Bit-widths arrive as *traced scalars* (runtime inputs of the exported
HLO), so a single executable serves the entire bit sweep of Tables 1–2.
"""

from __future__ import annotations

from functools import partial

import jax.numpy as jnp

from .config import QuantConfig
from .kernels import (
    fake_quant_pallas,
    muxq_decompose_pallas,
    muxq_fused_fq_pallas,
    quant_matmul_pallas,
)
from .kernels import ref

# Set False to bypass pallas_call and use the jnp reference (used by the
# AOT exporter's --no-pallas escape hatch and by A/B tests).
USE_PALLAS = True


def _fq(x, scale, qmax):
    if USE_PALLAS:
        return fake_quant_pallas(x, scale, qmax)
    return ref.fake_quant(x, scale, qmax)


def _decompose(x, mask, exp_factor):
    if USE_PALLAS:
        return muxq_decompose_pallas(x, mask, exp_factor)
    return ref.muxq_decompose(x, mask, exp_factor)


def _act_axis(granularity: str):
    """Reduction axis for activation scales on [T, K]."""
    return 1 if granularity == "per-vector" else None  # per-token rows


def _w_axis(granularity: str):
    """Reduction axis for weight scales on [K, N]."""
    return 0 if granularity == "per-vector" else None  # per-out-channel


def _scale(x, qmax, axis):
    s = ref.absmax_scale(x, qmax, axis=axis)
    if axis is None:
        s = s.reshape(1, 1)
    return s


def _scale_from_absmax(abs_x, qmax, axis):
    """Scale from a precomputed |x| array (avoids re-materializing the
    decomposed Body/Aux just to reduce them)."""
    m = jnp.max(abs_x, axis=axis, keepdims=axis is not None)
    s = jnp.maximum(m, ref.EPS) / qmax
    if axis is None:
        s = s.reshape(1, 1)
    return s


def quantize_weight(w, qcfg: QuantConfig, w_qmax, mask=None):
    """Fake-quantize a weight matrix [K, N] per the variant config.

    ``mask`` ([1,K] outlier-channel mask) is only consulted by llmint8,
    which keeps the rows feeding outlier channels in FP.
    """
    axis = _w_axis(qcfg.granularity)
    sw = _scale(w, w_qmax, axis)
    wq = _fq(w, sw, w_qmax)
    if qcfg.method == "llmint8" and mask is not None:
        row_mask = mask.reshape(-1, 1)
        wq = wq * (1.0 - row_mask) + w * row_mask
    return wq


def quantize_act(x, qcfg: QuantConfig, ia_qmax):
    """Fake-quantize activations [T, K] per the variant config. Returns
    (x_hat, mask) — mask is needed by llmint8's weight side."""
    axis = _act_axis(qcfg.granularity)
    if qcfg.method == "fp16":
        return x, None
    if qcfg.method == "naive":
        sx = _scale(x, ia_qmax, axis)
        return _fq(x, sx, ia_qmax), None

    mask = ref.outlier_mask(x, qcfg.theta)
    if qcfg.method == "muxq":
        # scales are computed on the decomposed Body/Aux via the cheap
        # closed form (Body/Aux are elementwise masks of x, so their
        # abs-max reductions can be taken on masked views without
        # materializing them)
        inv = jnp.exp2(-jnp.asarray(float(qcfg.exp_factor), x.dtype))
        shifted = jnp.abs(x) * inv
        body_abs = jnp.where(mask > 0, shifted, jnp.abs(x))
        aux_abs = shifted * mask
        s_body = _scale_from_absmax(body_abs, ia_qmax, axis)
        s_aux = _scale_from_absmax(aux_abs, ia_qmax, axis)
        if USE_PALLAS:
            # fused single-pass kernel (EXPERIMENTS.md §Perf L1): one HBM
            # round-trip instead of four
            return muxq_fused_fq_pallas(
                x, mask, s_body, s_aux, ia_qmax, float(qcfg.exp_factor)
            ), mask
        body, aux = _decompose(x, mask, float(qcfg.exp_factor))
        body_q = _fq(body, s_body, ia_qmax)
        aux_q = _fq(aux, s_aux, ia_qmax)
        return ref.muxq_reconstruct(body_q, aux_q, float(qcfg.exp_factor)), mask
    if qcfg.method == "llmint8":
        x_norm = x * (1.0 - mask)
        sx = _scale(x_norm, ia_qmax, axis)
        return _fq(x_norm, sx, ia_qmax) + x * mask, mask
    raise ValueError(f"unknown method {qcfg.method!r}")


def quant_linear(x, w, b, qcfg: QuantConfig, ia_qmax, w_qmax, smooth_s=None):
    """Quantized linear y = Q(x') @ Q(w') + b with optional SmoothQuant
    migration x' = x/s, w' = s*w (``smooth_s``: per-channel [K] scales from
    calibration).

    x: [T, K] activations; w: [K, N]; b: [N] or None.
    """
    if qcfg.method == "fp16":
        y = x @ w
        return y + b if b is not None else y

    if qcfg.smooth and smooth_s is not None:
        x = x / smooth_s.reshape(1, -1)
        w = w * smooth_s.reshape(-1, 1)

    x_hat, mask = quantize_act(x, qcfg, ia_qmax)
    w_hat = quantize_weight(w, qcfg, w_qmax, mask=mask)
    y = x_hat @ w_hat
    return y + b if b is not None else y


def quant_linear_int(x, w, qcfg: QuantConfig, ia_qmax, w_qmax):
    """True INT pipeline variant (quantize -> int matmul -> dequant) via
    the fused Pallas kernel — the serving hot path. Only 'naive' and
    'muxq' are expressible as pure INT GEMMs (that is the paper's point:
    llmint8's FP16 side stays FP)."""
    axis_x = _act_axis(qcfg.granularity)
    axis_w = _w_axis(qcfg.granularity)
    sw = _scale(w, w_qmax, axis_w)
    if qcfg.method == "naive":
        sx = _scale(x, ia_qmax, axis_x)
        return quant_matmul_pallas(x, w, sx, sw, ia_qmax)
    if qcfg.method == "muxq":
        mask = ref.outlier_mask(x, qcfg.theta)
        body, aux = _decompose(x, mask, float(qcfg.exp_factor))
        s_body = _scale(body, ia_qmax, axis_x)
        s_aux = _scale(aux, ia_qmax, axis_x)
        y_body = quant_matmul_pallas(body, w, s_body, sw, ia_qmax)
        y_aux = quant_matmul_pallas(aux, w, s_aux, sw, ia_qmax)
        f = jnp.exp2(float(qcfg.exp_factor)) - 1.0
        return y_body + f * y_aux
    raise ValueError(f"int pipeline supports naive|muxq, got {qcfg.method!r}")
