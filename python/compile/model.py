"""L2 — GPT-2 in JAX with pluggable quantized projections.

Architecture follows HF GPT-2 (the paper's testbed): learned positional
embeddings, pre-LN blocks, Conv1D-convention projections (weights stored
[in, out]), GELU MLP with d_ff = 4d, tied LM head. Quantization is applied
to exactly the four projections the paper targets (§4.3): ``c_attn``, the
attention ``c_proj``, ``c_fc`` and the MLP ``c_proj``.

Everything is a pure function over a params pytree, so the same code
serves training (FP, no quant), calibration, and the exported eval /
logits graphs (quantized, bit-widths as traced scalars).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, QuantConfig
from .kernels import ref
from .quant import quant_linear

#: the four quantized projection sites, in block order
PROJ_SITES = ("c_attn", "attn_proj", "c_fc", "mlp_proj")


# ------------------------------------------------------------------ init
def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """GPT-2 initialization (N(0, 0.02), residual projections scaled by
    1/sqrt(2L) as in the GPT-2 paper)."""
    rng = np.random.default_rng(seed)
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.n_layer

    def norm(*shape, std=0.02):
        return jnp.asarray(rng.normal(0.0, std, size=shape).astype(np.float32))

    res_std = 0.02 / np.sqrt(2.0 * L)
    params = {
        "wte": norm(v, d),
        "wpe": norm(cfg.n_ctx, d, std=0.01),
        "ln_f": {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)},
        "blocks": [],
    }
    for _ in range(L):
        params["blocks"].append({
            "ln_1": {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)},
            "c_attn": {"w": norm(d, 3 * d), "b": jnp.zeros((3 * d,), jnp.float32)},
            "attn_proj": {"w": norm(d, d, std=res_std), "b": jnp.zeros((d,), jnp.float32)},
            "ln_2": {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)},
            "c_fc": {"w": norm(d, cfg.d_ff), "b": jnp.zeros((cfg.d_ff,), jnp.float32)},
            "mlp_proj": {"w": norm(cfg.d_ff, d, std=res_std), "b": jnp.zeros((d,), jnp.float32)},
        })
    return params


# --------------------------------------------------------------- helpers
def layer_norm(x, g, b, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def gelu(x):
    """tanh-approximate GELU (the GPT-2 variant)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x ** 3)))


def _proj(x2d, wb, site, qctx):
    """Apply one (possibly quantized) projection on flattened tokens."""
    if qctx is None:
        return x2d @ wb["w"] + wb["b"]
    qcfg, ia_qmax, w_qmax, smooth = qctx
    s = smooth.get(site) if smooth else None
    return quant_linear(x2d, wb["w"], wb["b"], qcfg, ia_qmax, w_qmax, smooth_s=s)


# --------------------------------------------------------------- forward
def forward(params: dict, tokens, cfg: ModelConfig,
            qcfg: Optional[QuantConfig] = None,
            ia_bits=None, w_bits=None,
            smooth_per_block: Optional[list] = None,
            capture: Optional[dict] = None):
    """Run the model. tokens: i32 [B, S] -> logits f32 [B, S, V].

    * ``qcfg is None`` — pure FP forward (training / calibration).
    * otherwise the four projection sites are quantized with runtime
      ``ia_bits`` / ``w_bits`` scalars.
    * ``capture`` — optional dict; when given, per-site input-activation
      abs-max vectors are recorded (calibration & Fig.1 data).
    """
    B, S = tokens.shape
    d = cfg.d_model
    qctx_base = None
    if qcfg is not None and qcfg.method != "fp16":
        ia_qmax = ref.qmax_from_bits(jnp.asarray(ia_bits, jnp.float32))
        w_qmax = ref.qmax_from_bits(jnp.asarray(w_bits, jnp.float32))
    else:
        ia_qmax = w_qmax = None

    pos = jnp.arange(S)
    h = params["wte"][tokens] + params["wpe"][pos][None, :, :]

    for li, blk in enumerate(params["blocks"]):
        smooth = smooth_per_block[li] if smooth_per_block else None
        qctx = (qcfg, ia_qmax, w_qmax, smooth) if ia_qmax is not None else None

        # ---- attention
        x = layer_norm(h, blk["ln_1"]["g"], blk["ln_1"]["b"])
        x2 = x.reshape(B * S, d)
        if capture is not None:
            capture[(li, "c_attn")] = jnp.max(jnp.abs(x2), axis=0)
        qkv = _proj(x2, blk["c_attn"], "c_attn", qctx).reshape(B, S, 3 * d)
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(B, S, cfg.n_head, cfg.d_head).transpose(0, 2, 1, 3)

        q, k, v = heads(q), heads(k), heads(v)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(cfg.d_head)
        causal = jnp.tril(jnp.ones((S, S), bool))
        att = jnp.where(causal[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        o = o.transpose(0, 2, 1, 3).reshape(B * S, d)
        if capture is not None:
            capture[(li, "attn_proj")] = jnp.max(jnp.abs(o), axis=0)
        h = h + _proj(o, blk["attn_proj"], "attn_proj", qctx).reshape(B, S, d)

        # ---- MLP
        x = layer_norm(h, blk["ln_2"]["g"], blk["ln_2"]["b"])
        x2 = x.reshape(B * S, d)
        if capture is not None:
            capture[(li, "c_fc")] = jnp.max(jnp.abs(x2), axis=0)
        u = gelu(_proj(x2, blk["c_fc"], "c_fc", qctx))
        if capture is not None:
            capture[(li, "mlp_proj")] = jnp.max(jnp.abs(u), axis=0)
        h = h + _proj(u, blk["mlp_proj"], "mlp_proj", qctx).reshape(B, S, d)

    h = layer_norm(h, params["ln_f"]["g"], params["ln_f"]["b"])
    logits = h @ params["wte"].T  # tied head (not quantized, per the paper)
    return logits


# ------------------------------------------------------------------ loss
def nll_per_seq(params, tokens, cfg, **kw):
    """Per-sequence next-token NLL sums and token counts ([B], [B]).

    Predicts tokens[:, 1:] from tokens[:, :-1]. Per-sequence outputs let
    the rust dynamic batcher serve *mixed* batches (each request gets its
    own nll back, padding rows are discarded) while Table-1 shards still
    aggregate exactly: ppl = exp(sum nll / sum count).
    """
    logits = forward(params, tokens, cfg, **kw)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    tok_ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    counts = jnp.full((tokens.shape[0],), tokens.shape[1] - 1, jnp.float32)
    return -jnp.sum(tok_ll, axis=1), counts


def nll_sums(params, tokens, cfg, **kw):
    """Batch-summed NLL and token count (training / quick eval)."""
    s, c = nll_per_seq(params, tokens, cfg, **kw)
    return jnp.sum(s), jnp.sum(c)


def lm_loss(params, tokens, cfg):
    s, c = nll_sums(params, tokens, cfg)
    return s / c


# -------------------------------------------------- outlier injection
def inject_outliers(params: dict, cfg: ModelConfig, channels_per_block: int,
                    alpha: float, seed: int = 7) -> dict:
    """Function-preserving outlier injection (DESIGN.md §2).

    For each block and each of the two post-LN sites, scale ``k`` LN gain
    channels by ``alpha`` and the matching rows of the consuming projection
    by 1/alpha. The FP forward is unchanged (the factors cancel through
    the linear map) but the *activations* feeding c_attn / c_fc now carry
    genuine outlier channels — the exact phenomenon the paper handles.
    LN beta is scaled too so the affine part also cancels.
    """
    rng = np.random.default_rng(seed)
    out = jax.tree_util.tree_map(lambda t: t, params)  # shallow-ish copy
    new_blocks = []
    for blk in out["blocks"]:
        nb = {k: dict(v) for k, v in blk.items()}
        for ln_name, proj_name in (("ln_1", "c_attn"), ("ln_2", "c_fc")):
            d = nb[ln_name]["g"].shape[0]
            ch = rng.choice(d, size=channels_per_block, replace=False)
            scale = np.ones((d,), np.float32)
            scale[ch] = alpha
            s = jnp.asarray(scale)
            nb[ln_name] = {"g": nb[ln_name]["g"] * s, "b": nb[ln_name]["b"] * s}
            nb[proj_name] = {
                "w": nb[proj_name]["w"] / s[:, None],
                "b": nb[proj_name]["b"],
            }
        new_blocks.append(nb)
    out["blocks"] = new_blocks
    return out
