"""Deterministic 64-bit PRNG (splitmix64) mirrored bit-for-bit in
``rust/src/data/prng.rs``.

The synthetic-corpus generator and workload generators on both sides of the
language boundary must be able to reproduce identical streams, so we do not
use ``random``/``numpy`` here. splitmix64 is the standard seeding PRNG from
Vigna (2015): tiny, fast, passes BigCrush when used as a stream.
"""

from __future__ import annotations

MASK64 = (1 << 64) - 1


class SplitMix64:
    """splitmix64 stream. ``next_u64`` advances the state by the golden
    gamma and finalizes with the murmur3-style mixer."""

    __slots__ = ("state",)

    def __init__(self, seed: int) -> None:
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 bits of entropy (same construction as
        the rust twin: take the top 53 bits)."""
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def next_below(self, n: int) -> int:
        """Uniform integer in [0, n). Uses the (slightly biased for huge n,
        identical on both sides) multiply-shift reduction."""
        if n <= 0:
            raise ValueError("next_below requires n > 0")
        return (self.next_u64() * n) >> 64

    def next_range(self, lo: int, hi: int) -> int:
        """Uniform integer in [lo, hi] inclusive."""
        if hi < lo:
            raise ValueError("next_range requires hi >= lo")
        return lo + self.next_below(hi - lo + 1)


def mix(*vals: int) -> int:
    """Hash a tuple of integers into a 64-bit value, deterministically and
    identically to the rust twin (fold through one splitmix64 step each)."""
    h = 0x243F6A8885A308D3  # pi fractional bits
    for v in vals:
        h = (h ^ (v & MASK64)) & MASK64
        # one splitmix64 finalization round per element
        h = (h + 0x9E3779B97F4A7C15) & MASK64
        h = ((h ^ (h >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        h = ((h ^ (h >> 27)) * 0x94D049BB133111EB) & MASK64
        h = (h ^ (h >> 31)) & MASK64
    return h


def zipf_index(rng: SplitMix64, n: int, s: float = 1.05) -> int:
    """Sample an index in [0, n) with an (approximately) Zipfian
    distribution of exponent ``s`` via inverse-CDF on the harmonic weights.

    To stay cheap and identical across languages we use the closed-form
    approximation: u ~ U(0,1), idx = floor(n^(u^k)) - 1 style curves are
    fiddly, so instead we use rejection-free bounded pareto:
        x = (1 - u)^(-1/(s-epsilon_guard)) ... (heavy tail clipped to n)
    """
    u = rng.next_f64()
    # bounded Pareto inverse CDF over [1, n]
    alpha = max(s, 0.2)
    lo = 1.0
    hi = float(n)
    num = (hi ** alpha) * (lo ** alpha)
    den = u * (lo ** alpha) + (1.0 - u) * (hi ** alpha)
    x = (num / den) ** (1.0 / alpha)
    idx = int(x) - 1
    if idx < 0:
        idx = 0
    if idx >= n:
        idx = n - 1
    return idx
