"""Flat tensor-container format shared with rust (``rust/src/data/tensors.rs``).

Layout (little-endian):

    magic   8 bytes  b"MUXQTNSR"
    version u32      1
    count   u32
    per tensor:
        name_len u16, name utf-8
        dtype    u8   (0 = f32, 1 = i32, 2 = u8)
        ndim     u8
        dims     u32 * ndim
        data     raw little-endian

Used for model weights (``artifacts/weights/<model>.bin``), goldens
(``artifacts/goldens/*.bin``) and calibration data. Deliberately trivial so
the rust reader needs no external crates.
"""

from __future__ import annotations

import struct
from pathlib import Path

import numpy as np

MAGIC = b"MUXQTNSR"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1, np.dtype(np.uint8): 2}
DTYPES_REV = {0: np.float32, 1: np.int32, 2: np.uint8}


def write_tensors(path, tensors: dict) -> None:
    """tensors: {name: np.ndarray} (f32/i32/u8)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                arr = arr.astype(np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_tensors(path) -> dict:
    with open(path, "rb") as f:
        data = f.read()
    assert data[:8] == MAGIC, "bad magic"
    ver, count = struct.unpack_from("<II", data, 8)
    assert ver == 1
    off = 16
    out = {}
    for _ in range(count):
        (nlen,) = struct.unpack_from("<H", data, off)
        off += 2
        name = data[off: off + nlen].decode("utf-8")
        off += nlen
        dt, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = struct.unpack_from(f"<{ndim}I", data, off)
        off += 4 * ndim
        dtype = np.dtype(DTYPES_REV[dt])
        n = int(np.prod(dims)) if ndim else 1
        arr = np.frombuffer(data, dtype=dtype, count=n, offset=off).reshape(dims)
        off += n * dtype.itemsize
        out[name] = arr
    return out


def params_to_tensors(params: dict) -> dict:
    """Flatten the model pytree into {path: array} with '/'-joined keys
    (blocks indexed as block<NN>)."""
    flat = {}
    flat["wte"] = np.asarray(params["wte"])
    flat["wpe"] = np.asarray(params["wpe"])
    flat["ln_f/g"] = np.asarray(params["ln_f"]["g"])
    flat["ln_f/b"] = np.asarray(params["ln_f"]["b"])
    for i, blk in enumerate(params["blocks"]):
        for mod, sub in blk.items():
            for pname, arr in sub.items():
                flat[f"block{i:02d}/{mod}/{pname}"] = np.asarray(arr)
    return flat


def tensors_to_params(flat: dict, n_layer: int) -> dict:
    params = {
        "wte": flat["wte"], "wpe": flat["wpe"],
        "ln_f": {"g": flat["ln_f/g"], "b": flat["ln_f/b"]},
        "blocks": [],
    }
    for i in range(n_layer):
        blk: dict = {}
        prefix = f"block{i:02d}/"
        for key, arr in flat.items():
            if key.startswith(prefix):
                _, mod, pname = key.split("/")
                blk.setdefault(mod, {})[pname] = arr
        params["blocks"].append(blk)
    return params
