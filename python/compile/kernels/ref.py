"""Pure-jnp reference oracle for every Pallas kernel.

These functions define the *semantics* the kernels must match bit-for-bit
(pytest asserts allclose with tight tolerances; integer-valued paths must be
exact). The rust quantization engine (``rust/src/quant``) is additionally
cross-validated against goldens produced from these references.

Conventions
-----------
* symmetric abs-max quantization, qmax = 2^(bits-1) - 1
* rounding is round-half-to-even (jnp.round / IEEE rint) — the rust twin
  implements rint explicitly because ``f32::round`` rounds half away from 0
* scales are floored at EPS to avoid division by zero on all-zero slices
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def qmax_from_bits(bits):
    """2^(bits-1) - 1 for scalar/array ``bits`` (float ok: runtime input)."""
    return jnp.exp2(bits - 1.0) - 1.0


def absmax_scale(x, qmax, axis=None):
    """Abs-max scale over ``axis`` (None = per-tensor). Keeps dims so the
    result broadcasts against x."""
    m = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(m, EPS) / qmax


def quantize(x, scale, qmax):
    """FP -> integer grid (values are integers stored in f32)."""
    return jnp.clip(jnp.round(x / scale), -qmax, qmax)


def fake_quant(x, scale, qmax):
    """quantize -> dequantize (the paper's evaluation pipeline, §4.3)."""
    return quantize(x, scale, qmax) * scale


def quant_matmul(x, w, sx, sw, qmax_x, qmax_w):
    """True INT pipeline: quantize both operands, integer matmul, dequant.

    ``sx`` broadcasts over x (per-token: [M,1]; per-tensor: [1,1]);
    ``sw`` broadcasts over w's columns (per-out-channel: [1,N]; [1,1]).
    Equals fake_quant(x)@fake_quant(w) exactly because the scales factor out
    of the integer matmul.
    """
    xq = quantize(x, sx, qmax_x)
    wq = quantize(w, sw, qmax_w)
    return (xq @ wq) * (sx * sw)


def outlier_mask(x, theta):
    """Per-channel outlier mask (LLM.int8() criterion): channel j is an
    outlier iff any row has |x[i, j]| > theta. Returns float [1, N]."""
    return (jnp.max(jnp.abs(x), axis=0, keepdims=True) > theta).astype(x.dtype)


def muxq_decompose(x, mask, exp_factor):
    """MUXQ outlier decomposition (paper eqs. 4-6).

    Body  = x with outlier columns divided by 2^exp_factor
    Aux   = outlier columns divided by 2^exp_factor, zeros elsewhere
    Identity: x == Body + (2^exp_factor - 1) * Aux   (exact in FP)
    """
    inv = jnp.exp2(-jnp.asarray(exp_factor, x.dtype))
    body = x * (mask * inv + (1.0 - mask))
    aux = x * (mask * inv)
    return body, aux


def muxq_reconstruct(body, aux, exp_factor):
    f = jnp.exp2(jnp.asarray(exp_factor, body.dtype)) - 1.0
    return body + f * aux


def fq_naive(x, qmax, axis):
    """Naive abs-max fake quant of a full tensor at given granularity."""
    s = absmax_scale(x, qmax, axis=axis)
    return fake_quant(x, s, qmax)


def fq_muxq(x, qmax, axis, theta, exp_factor):
    """MUXQ fake-quant of activations: decompose, quantize Body and Aux
    each with their own (reduced-range) scales, reconstruct."""
    mask = outlier_mask(x, theta)
    body, aux = muxq_decompose(x, mask, exp_factor)
    s_body = absmax_scale(body, qmax, axis=axis)
    s_aux = absmax_scale(aux, qmax, axis=axis)
    body_q = fake_quant(body, s_body, qmax)
    aux_q = fake_quant(aux, s_aux, qmax)
    return muxq_reconstruct(body_q, aux_q, exp_factor)


def fq_llmint8_act(x, qmax, axis, theta):
    """LLM.int8() activation side: outlier columns stay FP, the rest is
    fake-quantized with scales computed over non-outlier entries only."""
    mask = outlier_mask(x, theta)
    x_norm = x * (1.0 - mask)
    s = absmax_scale(x_norm, qmax, axis=axis)
    return fake_quant(x_norm, s, qmax) + x * mask


def fq_llmint8_weight(w, qmax, axis, mask):
    """LLM.int8() weight side: rows feeding outlier channels stay FP."""
    row_mask = mask.reshape(-1, 1)  # [K,1]
    wq = fq_naive(w, qmax, axis)
    return wq * (1.0 - row_mask) + w * row_mask


def smooth_scales(act_absmax, w, alpha):
    """SmoothQuant per-channel migration scale:
    s_j = max|X_j|^alpha / max|W_j|^(1-alpha), clipped to >= EPS."""
    wmax = jnp.max(jnp.abs(w), axis=1)  # per input channel
    a = jnp.maximum(act_absmax, EPS) ** alpha
    b = jnp.maximum(wmax, EPS) ** (1.0 - alpha)
    return jnp.maximum(a / b, EPS)
