"""Pallas kernel for the MUXQ outlier decomposition (paper §3.3).

Fuses the three steps that a naive implementation would do in three HBM
passes — apply the outlier mask, shift (divide by 2^exp_factor), and split
into Body / Aux — into ONE pass over the activation tile:

    Body = x * (mask * 2^-exp + (1 - mask))     (outlier cols shifted)
    Aux  = x * (mask * 2^-exp)                  (only outlier cols, shifted)

so that   x == Body + (2^exp - 1) * Aux   holds exactly in FP.

The mask is a per-channel [1, N] vector computed by the caller (it is a
column-wise reduction over the *whole* activation matrix, i.e. a different
dataflow, and reuses :func:`..absmax.absmax_rows_pallas` on x^T). ``inv``
(= 2^-exp_factor) arrives as a runtime (1,1) scalar so one compiled kernel
serves every exp_factor ablation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import pick_block

INTERPRET = True


def _muxq_kernel(x_ref, m_ref, inv_ref, body_ref, aux_ref):
    x = x_ref[...]
    mask = m_ref[...]
    inv = inv_ref[0, 0]
    shifted = mask * inv
    body_ref[...] = x * (shifted + (1.0 - mask))
    aux_ref[...] = x * shifted


def muxq_decompose_pallas(x, mask, exp_factor):
    """Decompose ``x`` [M,N] given per-channel ``mask`` [1,N] into
    (Body, Aux). ``exp_factor`` may be a python int or a traced scalar."""
    m, n = x.shape
    bm, bn = pick_block(m), pick_block(n)
    inv = jnp.exp2(-jnp.asarray(exp_factor, x.dtype)).reshape(1, 1)
    body, aux = pl.pallas_call(
        _muxq_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), x.dtype),
            jax.ShapeDtypeStruct((m, n), x.dtype),
        ],
        interpret=INTERPRET,
    )(x, mask, inv)
    return body, aux
