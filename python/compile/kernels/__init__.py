"""L1 — Pallas kernels (build-time only; lowered into the exported HLO).

Public surface:

* :mod:`.ref` — pure-jnp oracle defining kernel semantics
* :func:`.absmax.absmax_rows_pallas`, :func:`.absmax.fake_quant_pallas`
* :func:`.muxq.muxq_decompose_pallas`
* :func:`.qmatmul.quant_matmul_pallas`
"""

from . import ref  # noqa: F401
from .absmax import absmax_rows_pallas, fake_quant_pallas  # noqa: F401
from .muxq import muxq_decompose_pallas  # noqa: F401
from .muxq_fused import muxq_fused_fq_pallas  # noqa: F401
from .qmatmul import quant_matmul_pallas  # noqa: F401
