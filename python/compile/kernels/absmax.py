"""Pallas kernels for abs-max scale computation and fake quantization.

Two kernels:

* :func:`absmax_rows_pallas` — tiled reduction producing per-row abs-max
  (the per-token granularity). Per-tensor reduces the row result once more
  (cheap [M,1] -> [1,1] reduction, done in jnp by the caller).
* :func:`fake_quant_pallas` — tiled quantize->dequantize given
  precomputed scales at any granularity (per-row [M,1], per-col [1,N] or
  per-tensor [1,1]) and a runtime qmax scalar.

Hardware notes (DESIGN.md §Hardware-Adaptation): blocks are sized so one
(bm, bn) activation tile plus its scale vector fit VMEM; the scale lives in
a (bm,1)/(1,bn)/(1,1) block so the division broadcasts inside the VPU
without re-reading HBM. On CPU we run interpret=True (Mosaic custom-calls
cannot execute on the CPU PJRT plugin).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import pick_block

INTERPRET = True


# --------------------------------------------------------------- abs-max
def _absmax_rows_kernel(x_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    blk = jnp.max(jnp.abs(x_ref[...]), axis=1, keepdims=True)
    o_ref[...] = jnp.maximum(o_ref[...], blk)


def absmax_rows_pallas(x):
    """Per-row abs-max of a 2-D array -> [M, 1]."""
    m, n = x.shape
    bm, bn = pick_block(m), pick_block(n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _absmax_rows_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), x.dtype),
        interpret=INTERPRET,
    )(x)


# ------------------------------------------------------------ fake quant
def _fake_quant_kernel(x_ref, s_ref, q_ref, o_ref):
    s = s_ref[...]
    q = q_ref[0, 0]
    y = jnp.round(x_ref[...] / s)
    o_ref[...] = jnp.clip(y, -q, q) * s


def fake_quant_pallas(x, scale, qmax):
    """quantize->dequantize with a precomputed ``scale`` broadcastable to
    ``x`` ([M,1] per-row, [1,N] per-col, [1,1] per-tensor) and runtime
    ``qmax`` (scalar or 0-d array)."""
    m, n = x.shape
    sm, sn = scale.shape
    bm, bn = pick_block(m), pick_block(n)
    if sm == m and sn == 1:
        s_spec = pl.BlockSpec((bm, 1), lambda i, j: (i, 0))
    elif sm == 1 and sn == n:
        s_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    elif sm == 1 and sn == 1:
        s_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    else:
        raise ValueError(f"unsupported scale shape {scale.shape} for x {x.shape}")
    qarr = jnp.asarray(qmax, x.dtype).reshape(1, 1)
    return pl.pallas_call(
        _fake_quant_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            s_spec,
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, scale, qarr)
