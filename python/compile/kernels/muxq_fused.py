"""Fused MUXQ fake-quant kernel (perf pass, EXPERIMENTS.md §Perf L1).

The straightforward formulation runs FOUR memory passes over the
activation matrix per projection:

    decompose -> fake_quant(Body) -> fake_quant(Aux) -> reconstruct

Each pass is a full HBM round-trip on real hardware (and a separate
grid-loop in interpret mode). This kernel fuses all four into ONE pass:

    shifted = x * 2^-exp
    body    = mask ? shifted : x
    aux     = mask ? shifted : 0
    x_hat   = fq(body, s_body) + (2^exp - 1) * fq(aux, s_aux)

The scales are still computed outside (global reductions; XLA fuses them
with the surrounding graph). VMEM residency per grid step: one (bm, bn)
input tile + two scale vectors + the output tile — identical to the
plain fake-quant kernel, i.e. the fusion is free.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import pick_block

INTERPRET = True


def _muxq_fused_kernel(x_ref, m_ref, sb_ref, sa_ref, q_ref, inv_ref, f_ref, o_ref):
    x = x_ref[...]
    mask = m_ref[...]
    q = q_ref[0, 0]
    inv = inv_ref[0, 0]
    f = f_ref[0, 0]
    sb = sb_ref[...]
    sa = sa_ref[...]
    shifted = x * inv
    body = mask * shifted + (1.0 - mask) * x
    aux = mask * shifted
    body_q = jnp.clip(jnp.round(body / sb), -q, q) * sb
    aux_q = jnp.clip(jnp.round(aux / sa), -q, q) * sa
    o_ref[...] = body_q + f * aux_q


def _scale_spec(shape, m, n, bm, bn):
    if shape == (m, 1):
        return pl.BlockSpec((bm, 1), lambda i, j: (i, 0))
    if shape == (1, 1):
        return pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    raise ValueError(f"unsupported scale shape {shape}")


def muxq_fused_fq_pallas(x, mask, s_body, s_aux, qmax, exp_factor):
    """One-pass MUXQ fake quantization.

    x: [M, N]; mask: [1, N] (1.0 = outlier channel); s_body/s_aux: [M,1]
    per-token or [1,1] per-tensor scales (computed on the decomposed
    Body/Aux); qmax, exp_factor: runtime scalars.
    """
    m, n = x.shape
    bm, bn = pick_block(m), pick_block(n)
    e = jnp.asarray(exp_factor, x.dtype)
    inv = jnp.exp2(-e).reshape(1, 1)
    f = (jnp.exp2(e) - 1.0).reshape(1, 1)
    qarr = jnp.asarray(qmax, x.dtype).reshape(1, 1)
    scalar = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    return pl.pallas_call(
        _muxq_fused_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
            _scale_spec(s_body.shape, m, n, bm, bn),
            _scale_spec(s_aux.shape, m, n, bm, bn),
            scalar,
            scalar,
            scalar,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=INTERPRET,
    )(x, mask, s_body, s_aux, qarr, inv, f)
