"""Block-size selection shared by all Pallas kernels.

Pallas blocks must tile the array exactly (we never rely on implicit
padding so the same BlockSpecs are valid for a real Mosaic lowering).
``pick_block`` returns the largest power-of-two divisor of ``dim`` capped
at ``max_block``.

The 512 cap is the measured sweet spot (EXPERIMENTS.md §Perf L1): the
elementwise kernels (fake-quant, fused MUXQ) are grid-overhead-bound, so
larger tiles win, while a 512-row quant-matmul tile (512xK f32, K <= 1024
-> 2 MiB) still fits the ~16 MiB VMEM of a TPU core with double-buffering.
Raising the cap to 1024 gains ~12% in interpret mode but pushes the
matmul kernel's working set to the VMEM edge on real hardware.
"""

from __future__ import annotations


def pick_block(dim: int, max_block: int = 512) -> int:
    """Largest power-of-two divisor of ``dim``, capped at ``max_block``."""
    if dim <= 0:
        raise ValueError("dim must be positive")
    b = 1
    while b * 2 <= max_block and dim % (b * 2) == 0:
        b *= 2
    return b


def vmem_bytes_quant_matmul(bm: int, bk: int, bn: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency of one quant-matmul grid step (used by the
    DESIGN.md §Perf roofline estimate and the L1 perf tests)."""
    x_tile = bm * bk * dtype_bytes
    w_tile = bk * bn * dtype_bytes
    o_tile = bm * bn * dtype_bytes
    scales = (bm + bn + 2) * dtype_bytes
    return 2 * (x_tile + w_tile) + o_tile + scales  # 2x for double-buffering
