"""Pallas kernel for the fused quantized matmul (the paper's compute
hot-spot: every Conv1D projection in GPT-2 runs through this).

True INT pipeline semantics (quantize -> integer matmul -> dequantize):

    xq = clip(round(x / sx), -q, q)        # int grid, stored f32
    wq = clip(round(w / sw), -q, q)
    y  = (xq @ wq) * sx * sw

The scales factor out of the integer matmul, so this is numerically equal
to fake_quant(x) @ fake_quant(w) — pytest asserts both. Integer products
accumulate exactly in f32 for K·q² < 2^24, which holds for every shape in
this repo (K <= 1024, q <= 127); the Mosaic lowering would use an i32
accumulator on the MXU instead.

Grid is (M/bm, N/bn) with the full K dimension resident per step: K is at
most d_ff = 1024 here, so an (bm=128, K=1024) f32 x-tile is 512 KiB —
within VMEM with double buffering (see tiling.vmem_bytes_quant_matmul).
For larger K this kernel would add a third grid axis with an accumulator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiling import pick_block

INTERPRET = True


def _qmm_kernel(x_ref, w_ref, sx_ref, sw_ref, q_ref, o_ref):
    q = q_ref[0, 0]
    sx = sx_ref[...]
    sw = sw_ref[...]
    xq = jnp.clip(jnp.round(x_ref[...] / sx), -q, q)
    wq = jnp.clip(jnp.round(w_ref[...] / sw), -q, q)
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    o_ref[...] = acc * (sx * sw)


def quant_matmul_pallas(x, w, sx, sw, qmax):
    """Fused quantized matmul.

    x: [M, K]; w: [K, N]; sx: [M,1] or [1,1]; sw: [1,N] or [1,1];
    qmax: runtime scalar. Returns [M, N] f32.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    bm, bn = pick_block(m), pick_block(n)

    if sx.shape == (m, 1):
        sx_spec = pl.BlockSpec((bm, 1), lambda i, j: (i, 0))
    elif sx.shape == (1, 1):
        sx_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    else:
        raise ValueError(f"bad sx shape {sx.shape}")
    if sw.shape == (1, n):
        sw_spec = pl.BlockSpec((1, bn), lambda i, j: (0, j))
    elif sw.shape == (1, 1):
        sw_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    else:
        raise ValueError(f"bad sw shape {sw.shape}")

    qarr = jnp.asarray(qmax, x.dtype).reshape(1, 1)
    return pl.pallas_call(
        _qmm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            sx_spec,
            sw_spec,
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x, w, sx, sw, qarr)
