"""Build-time training of the sim GPT-2 family on the synthetic corpus.

Hand-rolled AdamW (optax is not available in the offline image) with cosine
decay + linear warmup. Training is FP32 and quantization-free; quantization
is strictly post-training, as in the paper.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .model import init_params, lm_loss


@dataclass
class TrainResult:
    params: dict
    final_loss: float
    steps: int
    seconds: float
    loss_curve: list


def batches(token_ids: np.ndarray, cfg: ModelConfig, steps: int, seed: int = 1):
    """Yield [batch, n_ctx+? ] -> we use windows of exactly n_ctx tokens."""
    rng = np.random.default_rng(seed)
    n = len(token_ids) - cfg.n_ctx - 1
    if n <= 0:
        raise ValueError("corpus too small for context length")
    for _ in range(steps):
        starts = rng.integers(0, n, size=cfg.train_batch)
        yield np.stack([token_ids[s: s + cfg.n_ctx] for s in starts]).astype(np.int32)


def adamw_init(params):
    zeros = lambda t: jnp.zeros_like(t)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "t": jnp.zeros((), jnp.float32),
    }


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.99, eps=1e-8,
                 weight_decay=0.01):
    t = state["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * weight_decay * p

    new_params = jax.tree_util.tree_map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "t": t}


def cosine_lr(base_lr: float, step, total: int, warmup: int = 40):
    warm = base_lr * (step + 1.0) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos)


def train(cfg: ModelConfig, token_ids: np.ndarray, seed: int = 0,
          log_every: int = 50, log=print) -> TrainResult:
    params = init_params(cfg, seed=seed)
    opt = adamw_init(params)
    total = cfg.train_steps

    @jax.jit
    def step_fn(params, opt, batch, step):
        loss, grads = jax.value_and_grad(lm_loss)(params, batch, cfg)
        lr = cosine_lr(cfg.lr, step, total)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    t0 = time.time()
    curve = []
    for i, batch in enumerate(batches(token_ids, cfg, total, seed=seed + 1)):
        params, opt, loss = step_fn(params, opt, jnp.asarray(batch), jnp.asarray(i, jnp.float32))
        if i % log_every == 0 or i == total - 1:
            lv = float(loss)
            curve.append((i, lv))
            log(f"  [{cfg.name}] step {i:4d}/{total} loss {lv:.4f} ppl {np.exp(lv):.2f}")
    return TrainResult(params, float(loss), total, time.time() - t0, curve)
